//! Execution backends behind the serving queue.
//!
//! The serving stack is **open**: anything that can run a batch of
//! feature rows implements [`ExecutionBackend`] and plugs into
//! [`Server`](super::server::Server), [`Router`](super::router::Router),
//! and [`Engine`](super::engine::Engine) as a `Box<dyn ExecutionBackend>`
//! — no crate enum to edit, no feature flag in the public API. The
//! crate ships three implementations:
//!
//! * [`ReferenceBackend`] — the pure-rust functional model (fast host
//!   path; fans kernels out under a [`Parallelism`] budget).
//! * [`SimulatorBackend`] — the cycle-level BEANNA simulator (numerics
//!   *and* device timing; reports `sim_cycles`).
//! * [`ShardedSimulatorBackend`] — N simulated arrays behind one AXI
//!   front-end with a modeled-cycle scheduler; bit-identical numerics,
//!   plus per-shard backlogs surfaced through
//!   [`ExecutionBackend::shard_depths`].
//! * `PjrtBackend` — the PJRT runtime executing AOT-compiled HLO
//!   artifacts. The *implementation* is gated behind the `pjrt` cargo
//!   feature (it needs the non-vendored `xla` crate) but the API is
//!   not: [`pjrt`] exists in every build and returns
//!   [`ServeError::Unavailable`] when the feature is off.

use anyhow::Result;

use super::error::ServeError;
use crate::bf16::Matrix;
use crate::nn::Network;
use crate::sim::{Accelerator, AcceleratorConfig};
use crate::util::par::Parallelism;

/// Output of one backend batch execution.
#[derive(Debug, Clone)]
pub struct BatchOutput {
    /// Logits, `batch × classes`.
    pub logits: Matrix,
    /// Simulated device cycles (simulator backend only).
    pub sim_cycles: Option<u64>,
}

/// Cumulative wire-health counters reported by backends that talk to a
/// remote process (see
/// [`ExecutionBackend::transport_stats`]). Both counters are
/// monotonically non-decreasing over a backend's lifetime.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransportStats {
    /// Successful re-dials after connection loss (the initial connect
    /// is not counted).
    pub reconnects: u64,
    /// Wire-level failures: write/read errors, decode failures,
    /// checksum mismatches, missed heartbeats. Worker-side *backend*
    /// errors (a typed error frame) are not transport errors.
    pub transport_errors: u64,
}

/// An execution target for batched inference.
///
/// Object-safe by design: the serving layer holds
/// `Box<dyn ExecutionBackend>`, so third-party engines (a remote
/// device, a sharded simulator, an FPGA driver) register by
/// implementing this trait — the coordinator's own backends get no
/// special treatment.
///
/// # Contract
///
/// * [`run_batch_with`](Self::run_batch_with) receives a dense
///   `batch × features` matrix whose width the serving layer has
///   already validated against [`input_width`](Self::input_width)
///   (when declared). It returns logits with one row per input row.
/// * Implementations must be deterministic: the same batch twice
///   yields identical logits (the conformance suite enforces this).
/// * Errors are returned, never encoded in the output; the serving
///   layer wraps them in [`ServeError::Backend`] and delivers them on
///   the response channel.
pub trait ExecutionBackend: Send {
    /// Run one batch (`batch × features`) under an explicit
    /// kernel-parallelism budget. Backends that manage their own
    /// threads (or model a single device) may ignore `par`.
    fn run_batch_with(&mut self, batch: &Matrix, par: Parallelism) -> Result<BatchOutput>;

    /// Short human-readable tag for metrics and logs ("ref", "sim", …).
    fn tag(&self) -> &str;

    /// Largest batch this backend accepts in one call, if bounded
    /// (e.g. shape-specialized compiled executables). The server clamps
    /// its batching policy to this.
    fn max_batch(&self) -> Option<usize> {
        None
    }

    /// Input feature width, when the backend knows it. Declaring it
    /// lets the serving layer reject mismatched requests at `submit`
    /// time; backends returning `None` get width-pinning from the
    /// first accepted request instead.
    fn input_width(&self) -> Option<usize> {
        None
    }

    /// Number of output classes, when known. Declaring it is a
    /// contract: the engine builder cross-checks it against the served
    /// model's config and the server rejects batches whose logit
    /// column count disagrees with it.
    fn num_classes(&self) -> Option<usize> {
        None
    }

    /// One-time warm-up hook, called by the server before it accepts
    /// traffic (load caches, fault in weights, compile kernels…).
    /// Default: no-op.
    fn warm(&mut self) {}

    /// Per-shard queue depths for multi-array backends: an
    /// absolute-load gauge of the work each shard still owes (the
    /// sharded simulator reports modeled cycles queued beyond its
    /// front-end's issue frontier — see
    /// [`ShardedAccelerator::shard_remaining_work`](crate::sim::ShardedAccelerator::shard_remaining_work)).
    /// It must reflect *total* remaining work, not relative skew: a
    /// device that balances its own shards internally still reports how
    /// loaded it is, which is what
    /// [`RoutePolicy::ModeledBacklog`](super::router::RoutePolicy::ModeledBacklog)
    /// compares across devices. The server polls this after each batch
    /// and surfaces the latest value in
    /// [`MetricsSnapshot::shard_depths`](super::metrics::MetricsSnapshot).
    /// Default: `None` (single-device backends).
    fn shard_depths(&self) -> Option<Vec<u64>> {
        None
    }

    /// Cumulative wire-health counters for backends that reach a
    /// remote process (see
    /// [`RemoteBackend`](crate::transport::RemoteBackend)). The server
    /// polls this after each batch — like
    /// [`shard_depths`](Self::shard_depths), latest value wins — and
    /// surfaces it as
    /// [`MetricsSnapshot::reconnects`](super::metrics::MetricsSnapshot::reconnects)
    /// /
    /// [`MetricsSnapshot::transport_errors`](super::metrics::MetricsSnapshot::transport_errors),
    /// so wire faults stay distinguishable from backend faults.
    /// Default: `None` (in-process backends have no wire).
    fn transport_stats(&self) -> Option<TransportStats> {
        None
    }

    /// Run one batch with the default (auto-sized) parallelism.
    fn run_batch(&mut self, batch: &Matrix) -> Result<BatchOutput> {
        self.run_batch_with(batch, Parallelism::default())
    }
}

/// Pure-rust reference model: the fast functional host path.
pub struct ReferenceBackend {
    net: Network,
}

impl ReferenceBackend {
    /// Reference backend over `net`.
    pub fn new(net: Network) -> Self {
        Self { net }
    }

    /// Boxed, ready for `Server`/`Router`/`EngineBuilder::backend`.
    pub fn boxed(net: Network) -> Box<dyn ExecutionBackend> {
        Box::new(Self::new(net))
    }
}

impl ExecutionBackend for ReferenceBackend {
    fn run_batch_with(&mut self, batch: &Matrix, par: Parallelism) -> Result<BatchOutput> {
        Ok(BatchOutput {
            logits: self.net.forward_with(batch, par)?,
            sim_cycles: None,
        })
    }

    fn tag(&self) -> &str {
        "ref"
    }

    fn input_width(&self) -> Option<usize> {
        Some(self.net.config.input_width())
    }

    fn num_classes(&self) -> Option<usize> {
        Some(self.net.config.num_classes())
    }
}

/// Cycle-level BEANNA simulator: numerics plus device timing.
pub struct SimulatorBackend {
    accel: Box<Accelerator>,
    net: Network,
}

impl SimulatorBackend {
    /// Simulator backend with the default device configuration.
    pub fn new(net: Network) -> Self {
        Self::with_config(net, AcceleratorConfig::default())
    }

    /// Simulator backend with an explicit device configuration.
    pub fn with_config(net: Network, config: AcceleratorConfig) -> Self {
        Self {
            accel: Box::new(Accelerator::new(config)),
            net,
        }
    }

    /// Boxed, ready for `Server`/`Router`/`EngineBuilder::backend`.
    pub fn boxed(net: Network) -> Box<dyn ExecutionBackend> {
        Box::new(Self::new(net))
    }
}

impl ExecutionBackend for SimulatorBackend {
    fn run_batch_with(&mut self, batch: &Matrix, _par: Parallelism) -> Result<BatchOutput> {
        // Command the device through its AXI-Lite front door, exactly
        // as driver software would (§III-D step 1). The simulator
        // models one device; the kernel-parallelism budget does not
        // apply to it.
        let mut axi = crate::sim::AxiRegisterFile::new();
        let report = self.accel.run_via_axi(&mut axi, &self.net, batch)?;
        debug_assert_eq!(axi.status(), crate::sim::axi::Status::Done);
        Ok(BatchOutput {
            logits: report.outputs,
            sim_cycles: Some(report.total_cycles),
        })
    }

    fn tag(&self) -> &str {
        "sim"
    }

    fn input_width(&self) -> Option<usize> {
        Some(self.net.config.input_width())
    }

    fn num_classes(&self) -> Option<usize> {
        Some(self.net.config.num_classes())
    }
}

/// Sharded cycle-level simulator: N systolic arrays behind one AXI
/// front-end, scheduled in **modeled cycles**
/// ([`sim::ShardedAccelerator`](crate::sim::ShardedAccelerator)).
///
/// Functionally bit-identical to [`SimulatorBackend`] — every command
/// executes on a full single-array device — but the device-level
/// scheduler (least-busy by default) spreads commands across shards on
/// the modeled clock, so `sim_cycles` stays the per-command execution
/// cost while [`report`](Self::report) exposes the modeled makespan and
/// per-shard utilization, and
/// [`shard_depths`](ExecutionBackend::shard_depths) feeds per-shard
/// backlogs into the serving metrics.
pub struct ShardedSimulatorBackend {
    dev: crate::sim::ShardedAccelerator,
    net: Network,
}

impl ShardedSimulatorBackend {
    /// Sharded simulator with `shards` arrays and the default device
    /// configuration (least-busy scheduling).
    pub fn new(net: Network, shards: usize) -> Self {
        Self::with_config(net, AcceleratorConfig::sharded(shards))
    }

    /// Sharded simulator over an explicit device configuration
    /// (`config.num_shards` sets the array count).
    pub fn with_config(net: Network, config: AcceleratorConfig) -> Self {
        Self {
            dev: crate::sim::ShardedAccelerator::new(config),
            net,
        }
    }

    /// Sharded simulator with an explicit device-level scheduling
    /// policy (the modeled-time JSQ-vs-round-robin comparisons use
    /// this).
    pub fn with_policy(
        net: Network,
        config: AcceleratorConfig,
        policy: crate::sim::ShardPolicy,
    ) -> Self {
        Self {
            dev: crate::sim::ShardedAccelerator::with_policy(config, policy),
            net,
        }
    }

    /// Boxed, ready for `Server`/`Router`/`EngineBuilder::backend`.
    pub fn boxed(net: Network, shards: usize) -> Box<dyn ExecutionBackend> {
        Box::new(Self::new(net, shards))
    }

    /// Number of array shards.
    pub fn num_shards(&self) -> usize {
        self.dev.num_shards()
    }

    /// Aggregated modeled-time report (makespan, per-shard utilization).
    pub fn report(&self) -> crate::sim::ShardedReport {
        self.dev.report()
    }
}

impl ExecutionBackend for ShardedSimulatorBackend {
    fn run_batch_with(&mut self, batch: &Matrix, _par: Parallelism) -> Result<BatchOutput> {
        let job = self.dev.submit(&self.net, batch)?;
        Ok(BatchOutput {
            logits: job.run.outputs,
            sim_cycles: Some(job.run.total_cycles),
        })
    }

    fn tag(&self) -> &str {
        "sharded-sim"
    }

    fn input_width(&self) -> Option<usize> {
        Some(self.net.config.input_width())
    }

    fn num_classes(&self) -> Option<usize> {
        Some(self.net.config.num_classes())
    }

    fn shard_depths(&self) -> Option<Vec<u64>> {
        // Remaining work past the front-end's issue frontier: stays
        // informative when the device-level scheduler balances its own
        // shards (where the relative imbalance gauge flatlines at ~0
        // regardless of load), and stays anchored to issued work for
        // the serving path's back-to-back submissions (arrival clock
        // parked at 0).
        Some(self.dev.shard_remaining_work())
    }
}

/// PJRT backend from an AOT artifact (`variant` = "hybrid"/"fp",
/// compiled at a fixed `batch` shape; smaller batches are zero-padded
/// and sliced).
///
/// This constructor is part of every build: when the crate is compiled
/// without the `pjrt` feature it returns [`ServeError::Unavailable`]
/// instead of failing to exist, so callers need no `#[cfg]` of their
/// own.
pub fn pjrt(
    paths: &crate::io::ArtifactPaths,
    variant: &str,
    batch: usize,
) -> Result<Box<dyn ExecutionBackend>, ServeError> {
    #[cfg(feature = "pjrt")]
    {
        Ok(Box::new(PjrtBackend::load(paths, variant, batch)?))
    }
    #[cfg(not(feature = "pjrt"))]
    {
        let _ = (paths, variant, batch);
        Err(ServeError::Unavailable(
            "this build has no PJRT support (rebuild with --features pjrt)".into(),
        ))
    }
}

/// A PJRT executable bundled with its **own private** client.
///
/// The `xla` crate's handles use `Rc` internally, so they are not
/// `Send`. This wrapper owns the client *and* every executable compiled
/// from it, so the entire `Rc` graph moves between threads as one unit
/// and is only ever touched by its current owner — which makes the
/// manual `Send` sound. Construct it on any thread, then hand it to the
/// server's worker; never clone pieces out of it.
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    // Field order matters: `exe` must drop before `client`.
    exe: crate::runtime::HloExecutable,
    _client: xla::PjRtClient,
}

// SAFETY: see type docs — the full ownership graph moves together and is
// accessed from exactly one thread at a time.
#[cfg(feature = "pjrt")]
unsafe impl Send for PjrtBackend {}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    /// Create a fresh client and compile the artifact for `variant` at
    /// the given fixed batch size.
    pub fn load(
        paths: &crate::io::ArtifactPaths,
        variant: &str,
        batch: usize,
    ) -> Result<Self, ServeError> {
        let mk = || -> Result<Self> {
            let client = xla::PjRtClient::cpu()?;
            let exe = crate::runtime::HloExecutable::load(
                &client,
                &paths.hlo(variant, batch),
                (batch, crate::data::IMG_PIXELS),
            )?;
            Ok(Self {
                exe,
                _client: client,
            })
        };
        // Load/compile failures are configuration problems (missing
        // artifact, client init), not runtime batch faults — callers
        // must be able to tell them apart from `ServeError::Backend`.
        mk().map_err(|e| ServeError::InvalidConfig(format!("pjrt load failed: {e:#}")))
    }
}

#[cfg(feature = "pjrt")]
impl ExecutionBackend for PjrtBackend {
    fn run_batch_with(&mut self, batch: &Matrix, _par: Parallelism) -> Result<BatchOutput> {
        use anyhow::ensure;
        let (fixed_batch, feat) = self.exe.input_shape;
        ensure!(
            batch.cols == feat,
            "pjrt backend expects {feat} features, got {}",
            batch.cols
        );
        ensure!(
            batch.rows <= fixed_batch,
            "batch {} exceeds compiled shape {fixed_batch}",
            batch.rows
        );
        let logits = if batch.rows == fixed_batch {
            self.exe.run(batch)?
        } else {
            // Zero-pad to the compiled batch, slice the result.
            let mut padded = Matrix::zeros(fixed_batch, feat);
            for r in 0..batch.rows {
                padded.row_mut(r).copy_from_slice(batch.row(r));
            }
            let full = self.exe.run(&padded)?;
            let mut out = Matrix::zeros(batch.rows, full.cols);
            for r in 0..batch.rows {
                out.row_mut(r).copy_from_slice(full.row(r));
            }
            out
        };
        Ok(BatchOutput {
            logits,
            sim_cycles: None,
        })
    }

    fn tag(&self) -> &str {
        "pjrt"
    }

    fn max_batch(&self) -> Option<usize> {
        Some(self.exe.input_shape.0)
    }

    fn input_width(&self) -> Option<usize> {
        Some(self.exe.input_shape.1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{NetworkConfig, Precision};

    fn tiny_net() -> Network {
        Network::random(
            &NetworkConfig {
                sizes: vec![784, 32, 10],
                precisions: vec![Precision::Bf16, Precision::Binary],
                front: None,
            },
            3,
        )
    }

    #[test]
    fn sim_and_reference_agree() {
        let net = tiny_net();
        let mut sim = SimulatorBackend::new(net.clone());
        let mut rf = ReferenceBackend::new(net);
        let x = Matrix::from_vec(
            4,
            784,
            crate::util::rng::Xoshiro256::seed_from_u64(9)
                .normal_vec(4 * 784)
                .iter()
                .map(|v| v.abs().min(1.0))
                .collect(),
        )
        .unwrap();
        let a = sim.run_batch(&x).unwrap();
        let b = rf.run_batch(&x).unwrap();
        assert_eq!(a.logits, b.logits);
        assert!(a.sim_cycles.unwrap() > 0);
        assert!(b.sim_cycles.is_none());
        assert_eq!(sim.tag(), "sim");
        assert_eq!(rf.tag(), "ref");
    }

    #[test]
    fn backends_declare_model_shape() {
        let rf = ReferenceBackend::new(tiny_net());
        assert_eq!(rf.input_width(), Some(784));
        assert_eq!(rf.num_classes(), Some(10));
        let sim = SimulatorBackend::new(tiny_net());
        assert_eq!(sim.input_width(), Some(784));
        assert_eq!(sim.num_classes(), Some(10));
        let sharded = ShardedSimulatorBackend::new(tiny_net(), 4);
        assert_eq!(sharded.input_width(), Some(784));
        assert_eq!(sharded.num_classes(), Some(10));
        assert_eq!(sharded.num_shards(), 4);
    }

    #[test]
    fn sharded_sim_matches_single_array_and_tracks_depths() {
        let net = tiny_net();
        let mut sharded = ShardedSimulatorBackend::new(net.clone(), 2);
        let mut single = SimulatorBackend::new(net);
        // Only multi-array backends report depths; singles return None.
        assert_eq!(single.shard_depths(), None);
        assert_eq!(sharded.shard_depths(), Some(vec![0, 0]));
        let x = Matrix::from_vec(
            3,
            784,
            crate::util::rng::Xoshiro256::seed_from_u64(21).normal_vec(3 * 784),
        )
        .unwrap();
        for _ in 0..2 {
            let a = sharded.run_batch(&x).unwrap();
            let b = single.run_batch(&x).unwrap();
            assert_eq!(a.logits, b.logits, "sharded shard diverged");
            assert_eq!(a.sim_cycles, b.sim_cycles, "per-command cycles diverged");
        }
        // Two equal commands under least-busy land one per shard; with
        // nothing yet executed on the modeled clock, *both* shards owe
        // their command's cycles beyond the issue frontier — the
        // remaining-work gauge sees the absolute load a relative
        // imbalance gauge would read as ~0 here.
        let depths = sharded.shard_depths().unwrap();
        assert_eq!(depths.len(), 2);
        assert!(depths.iter().all(|&d| d > 0), "{depths:?}");
        let report = sharded.report();
        assert_eq!(report.jobs, 2);
        assert!(report.makespan > 0);
    }

    #[test]
    fn reference_rejects_bad_width() {
        let mut rf = ReferenceBackend::new(tiny_net());
        assert!(rf.run_batch(&Matrix::zeros(1, 100)).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_constructor_reports_unavailable_without_feature() {
        let err = pjrt(&crate::io::ArtifactPaths::discover(), "hybrid", 16).unwrap_err();
        assert!(matches!(err, ServeError::Unavailable(_)));
    }

    #[test]
    fn trait_is_object_safe_for_third_parties() {
        // A backend defined entirely outside the crate's own impls.
        struct Constant(usize);
        impl ExecutionBackend for Constant {
            fn run_batch_with(&mut self, batch: &Matrix, _par: Parallelism) -> Result<BatchOutput> {
                Ok(BatchOutput {
                    logits: Matrix::zeros(batch.rows, self.0),
                    sim_cycles: None,
                })
            }
            fn tag(&self) -> &str {
                "const"
            }
        }
        let mut b: Box<dyn ExecutionBackend> = Box::new(Constant(5));
        let out = b.run_batch(&Matrix::zeros(3, 7)).unwrap();
        assert_eq!((out.logits.rows, out.logits.cols), (3, 5));
        assert_eq!(b.tag(), "const");
        assert_eq!(b.max_batch(), None);
        assert_eq!(b.input_width(), None);
    }
}
