//! Typed serving errors.
//!
//! Every failure on the serving path is a [`ServeError`] — delivered
//! either synchronously from `submit`/`start`/`build`, or on the
//! response channel as the `Err` arm of a [`ServeResult`]. No code path
//! signals failure through sentinel values (empty logits, `usize::MAX`
//! predictions): a response you receive is either a real
//! [`InferenceResponse`](super::request::InferenceResponse) or a typed
//! error you can match on.

use std::fmt;

/// What a submitted request resolves to: a real response or a typed
/// serving error. This is the payload type of every response channel.
pub type ServeResult = Result<super::request::InferenceResponse, ServeError>;

/// A typed serving-path failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The request's feature width does not match the model's input
    /// width. Rejected at `submit` time — mismatched requests never
    /// reach the worker thread, so they can neither panic it nor poison
    /// a batch.
    WidthMismatch {
        /// Input width the serving model expects.
        expected: usize,
        /// Width the request actually carried.
        got: usize,
    },
    /// The request carried no features at all.
    EmptyRequest,
    /// The engine has no model registered under this name.
    UnknownModel {
        /// The name that was asked for.
        name: String,
        /// Models that *are* registered (sorted).
        available: Vec<String>,
    },
    /// A configuration was rejected before any worker started
    /// (`max_batch == 0`, zero replicas, duplicate model names, …).
    InvalidConfig(String),
    /// The execution backend failed while running a batch. Carries the
    /// backend's `tag()` and the rendered error chain.
    Backend {
        /// `ExecutionBackend::tag()` of the failing backend.
        backend: String,
        /// Rendered error message.
        message: String,
    },
    /// The requested backend is not compiled into this build (e.g. the
    /// PJRT runtime without the `pjrt` feature).
    Unavailable(String),
    /// The server/engine was already shut down when the call was made.
    Stopped,
    /// The response channel disconnected before a response arrived
    /// (the worker exited while the request was in flight).
    ChannelClosed,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::WidthMismatch { expected, got } => write!(
                f,
                "request width mismatch: model expects {expected} features, got {got}"
            ),
            ServeError::EmptyRequest => write!(f, "request carries no features"),
            ServeError::UnknownModel { name, available } => write!(
                f,
                "unknown model '{name}' (available: {})",
                if available.is_empty() {
                    "none".to_string()
                } else {
                    available.join(", ")
                }
            ),
            ServeError::InvalidConfig(msg) => write!(f, "invalid serving config: {msg}"),
            ServeError::Backend { backend, message } => {
                write!(f, "backend '{backend}' failed: {message}")
            }
            ServeError::Unavailable(msg) => write!(f, "backend unavailable: {msg}"),
            ServeError::Stopped => write!(f, "server stopped"),
            ServeError::ChannelClosed => write!(f, "response channel closed"),
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_actionable() {
        let e = ServeError::WidthMismatch {
            expected: 784,
            got: 10,
        };
        assert!(e.to_string().contains("784"));
        assert!(e.to_string().contains("10"));
        let e = ServeError::UnknownModel {
            name: "gpt".into(),
            available: vec!["hybrid".into(), "fp".into()],
        };
        assert!(e.to_string().contains("gpt"));
        assert!(e.to_string().contains("hybrid"));
        let e = ServeError::UnknownModel {
            name: "x".into(),
            available: vec![],
        };
        assert!(e.to_string().contains("none"));
    }

    #[test]
    fn converts_into_anyhow() {
        // `ServeError: std::error::Error + Send + Sync`, so `?` works in
        // anyhow contexts (the CLI and examples rely on this).
        fn takes_anyhow() -> anyhow::Result<()> {
            Err(ServeError::Stopped)?
        }
        assert!(takes_anyhow().is_err());
    }
}
