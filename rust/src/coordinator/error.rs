//! Typed serving errors.
//!
//! Every failure on the serving path is a [`ServeError`] — delivered
//! either synchronously from `submit`/`start`/`build`, or on the
//! response channel as the `Err` arm of a [`ServeResult`]. No code path
//! signals failure through sentinel values (empty logits, `usize::MAX`
//! predictions): a response you receive is either a real
//! [`InferenceResponse`](super::request::InferenceResponse) or a typed
//! error you can match on.

use std::fmt;

/// What a submitted request resolves to: a real response or a typed
/// serving error. This is the payload type of every response channel.
pub type ServeResult = Result<super::request::InferenceResponse, ServeError>;

/// A typed serving-path failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The request's feature width does not match the model's input
    /// width. Rejected at `submit` time — mismatched requests never
    /// reach the worker thread, so they can neither panic it nor poison
    /// a batch.
    WidthMismatch {
        /// Input width the serving model expects.
        expected: usize,
        /// Width the request actually carried.
        got: usize,
    },
    /// The request carried no features at all.
    EmptyRequest,
    /// The engine has no model registered under this name.
    UnknownModel {
        /// The name that was asked for.
        name: String,
        /// Models that *are* registered (sorted).
        available: Vec<String>,
    },
    /// A configuration was rejected before any worker started
    /// (`max_batch == 0`, zero replicas, duplicate model names, …).
    InvalidConfig(String),
    /// The bounded admission queue is full for this request's
    /// priority class. Returned synchronously from
    /// `submit`/`submit_with` — overload is pushed back to the caller
    /// instead of growing an unbounded queue. Retry later, shed load,
    /// or route elsewhere.
    Overloaded {
        /// In-flight depth observed at rejection time.
        depth: usize,
        /// The admission limit applied to this request: the configured
        /// `queue_capacity` for `Interactive` traffic, or the (lower)
        /// bulk limit — capacity minus the interactive reserve — for
        /// `Bulk`. `depth >= capacity` always holds at rejection.
        capacity: usize,
    },
    /// The request's deadline passed while it was still queued; the
    /// batcher dropped it at batch-formation time — it never reached
    /// the backend.
    DeadlineExceeded {
        /// Microseconds the request had waited when it was dropped.
        waited_us: u64,
    },
    /// The request was withdrawn via
    /// [`Ticket::cancel`](super::request::Ticket::cancel) — or by
    /// dropping its unresolved ticket — before it was dispatched.
    Cancelled,
    /// The execution backend failed while running a batch. Carries the
    /// backend's `tag()` and the rendered error chain.
    Backend {
        /// `ExecutionBackend::tag()` of the failing backend.
        backend: String,
        /// Rendered error message.
        message: String,
    },
    /// The requested backend is not compiled into this build (e.g. the
    /// PJRT runtime without the `pjrt` feature).
    Unavailable(String),
    /// The server/engine is draining: admission is closed while
    /// already-queued work is flushed (see
    /// [`Server::begin_drain`](super::server::Server::begin_drain)).
    /// Unlike [`Stopped`](Self::Stopped), the worker is still running —
    /// in-flight tickets resolve normally; only *new* submissions are
    /// refused.
    ShuttingDown,
    /// The server/engine was already shut down when the call was made.
    Stopped,
    /// The response channel disconnected before a response arrived
    /// (the worker exited while the request was in flight).
    ChannelClosed,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::WidthMismatch { expected, got } => write!(
                f,
                "request width mismatch: model expects {expected} features, got {got}"
            ),
            ServeError::EmptyRequest => write!(f, "request carries no features"),
            ServeError::UnknownModel { name, available } => write!(
                f,
                "unknown model '{name}' (available: {})",
                if available.is_empty() {
                    "none".to_string()
                } else {
                    available.join(", ")
                }
            ),
            ServeError::InvalidConfig(msg) => write!(f, "invalid serving config: {msg}"),
            ServeError::Overloaded { depth, capacity } => write!(
                f,
                "server overloaded: {depth} requests in flight at capacity {capacity}"
            ),
            ServeError::DeadlineExceeded { waited_us } => write!(
                f,
                "deadline exceeded after {waited_us} µs queued (request never dispatched)"
            ),
            ServeError::Cancelled => write!(f, "request cancelled before dispatch"),
            ServeError::Backend { backend, message } => {
                write!(f, "backend '{backend}' failed: {message}")
            }
            ServeError::Unavailable(msg) => write!(f, "backend unavailable: {msg}"),
            ServeError::ShuttingDown => {
                write!(f, "server draining: admission closed, queued work is being flushed")
            }
            ServeError::Stopped => write!(f, "server stopped"),
            ServeError::ChannelClosed => write!(f, "response channel closed"),
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_actionable() {
        let e = ServeError::WidthMismatch {
            expected: 784,
            got: 10,
        };
        assert!(e.to_string().contains("784"));
        assert!(e.to_string().contains("10"));
        let e = ServeError::UnknownModel {
            name: "gpt".into(),
            available: vec!["hybrid".into(), "fp".into()],
        };
        assert!(e.to_string().contains("gpt"));
        assert!(e.to_string().contains("hybrid"));
        let e = ServeError::UnknownModel {
            name: "x".into(),
            available: vec![],
        };
        assert!(e.to_string().contains("none"));
        let e = ServeError::Overloaded {
            depth: 128,
            capacity: 128,
        };
        assert!(e.to_string().contains("128"));
        let e = ServeError::DeadlineExceeded { waited_us: 750 };
        assert!(e.to_string().contains("750"));
        assert!(ServeError::Cancelled.to_string().contains("cancelled"));
        assert!(ServeError::ShuttingDown.to_string().contains("draining"));
        // Drain and stop are distinct, matchable conditions.
        assert_ne!(ServeError::ShuttingDown, ServeError::Stopped);
    }

    #[test]
    fn converts_into_anyhow() {
        // `ServeError: std::error::Error + Send + Sync`, so `?` works in
        // anyhow contexts (the CLI and examples rely on this).
        fn takes_anyhow() -> anyhow::Result<()> {
            Err(ServeError::Stopped)?
        }
        assert!(takes_anyhow().is_err());
    }
}
