//! The `Engine` facade: multiple named models behind one submit
//! surface.
//!
//! An [`Engine`] owns one worker group ([`Router`]) per named model —
//! each group is `replicas` servers over backends produced by that
//! model's backend factory — and routes `submit(model, features)` to
//! the right group. Shapes come from each model's
//! [`NetworkConfig`](crate::nn::NetworkConfig): two models with
//! different input widths and class counts serve concurrently behind
//! the same engine, and every request is width-checked against *its*
//! model at submit time.
//!
//! Built fluently:
//!
//! ```
//! use beanna::coordinator::{Engine, SimulatorBackend, RoutePolicy, BatchPolicy};
//! use beanna::nn::{Network, NetworkConfig, Precision};
//!
//! let hybrid = Network::random(&NetworkConfig::beanna_hybrid(), 7);
//! let tiny = Network::random(&NetworkConfig::uniform(&[32, 16, 4], Precision::Bf16), 9);
//! let engine = Engine::builder()
//!     .model("hybrid", hybrid)
//!     .replicas(2)
//!     .backend(|net, _i| Ok(SimulatorBackend::boxed(net.clone())))
//!     .model("tiny", tiny) // defaults: 1 replica, reference backend
//!     .batch_policy(BatchPolicy::default())
//!     .route_policy(RoutePolicy::LeastOutstanding)
//!     .build()?;
//! let resp = engine.infer("tiny", vec![0.5; 32])?;
//! assert_eq!(resp.logits.len(), 4);
//! engine.shutdown();
//! # anyhow::Ok(())
//! ```
//!
//! Any [`ExecutionBackend`] factory plugs in the same way — e.g. a
//! replica of
//! [`ShardedSimulatorBackend`](super::backend::ShardedSimulatorBackend)
//! models a whole multi-array device per worker
//! (`.backend(|net, _i| Ok(ShardedSimulatorBackend::boxed(net.clone(), 4)))`),
//! and its per-shard queue depths surface through
//! [`Engine::metrics`] → [`MetricsSnapshot::shard_depths`].

use std::collections::BTreeMap;

use super::backend::{ExecutionBackend, ReferenceBackend};
use super::batcher::BatchPolicy;
use super::error::ServeError;
use super::metrics::{HealthState, MetricsSnapshot};
use super::request::{InferenceResponse, SubmitOptions};
use super::router::{RetryPolicy, RoutePolicy, RoutedTicket, Router};
use super::server::ServerConfig;
use crate::nn::Network;
use crate::util::par::Parallelism;

/// Produces one backend per replica for a model. Receives the model's
/// network and the replica index, so factories can clone weights into
/// per-replica engines or open per-replica devices.
pub type BackendFactory =
    Box<dyn FnMut(&Network, usize) -> Result<Box<dyn ExecutionBackend>, ServeError>>;

struct ModelSpec {
    name: String,
    net: Network,
    replicas: usize,
    factory: Option<BackendFactory>,
}

/// Fluent builder for an [`Engine`].
///
/// [`model`](Self::model) registers a named model;
/// [`replicas`](Self::replicas) and [`backend`](Self::backend) apply
/// to the most recently added model. [`batch_policy`](Self::batch_policy),
/// [`route_policy`](Self::route_policy), and
/// [`parallelism`](Self::parallelism) are engine-wide. Configuration
/// mistakes (knobs before any model, duplicate names, zero replicas)
/// are collected and reported together as
/// [`ServeError::InvalidConfig`] from [`build`](Self::build).
pub struct EngineBuilder {
    models: Vec<ModelSpec>,
    policy: BatchPolicy,
    route: RoutePolicy,
    retry: RetryPolicy,
    parallelism: Parallelism,
    queue_capacity: Option<usize>,
    pool_sized_batches: bool,
    errors: Vec<String>,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl EngineBuilder {
    /// Empty builder with default batching, round-robin routing, and
    /// auto-sized kernel parallelism.
    pub fn new() -> Self {
        Self {
            models: Vec::new(),
            policy: BatchPolicy::default(),
            route: RoutePolicy::RoundRobin,
            retry: RetryPolicy::default(),
            parallelism: Parallelism::default(),
            queue_capacity: None,
            pool_sized_batches: false,
            errors: Vec::new(),
        }
    }

    /// Register a named model. Defaults for the new model: one
    /// replica, [`ReferenceBackend`] over `net`. Shapes (input width,
    /// class count) are taken from `net.config`.
    pub fn model(mut self, name: &str, net: Network) -> Self {
        if self.models.iter().any(|m| m.name == name) {
            self.errors.push(format!("duplicate model name '{name}'"));
        }
        self.models.push(ModelSpec {
            name: name.to_string(),
            net,
            replicas: 1,
            factory: None,
        });
        self
    }

    /// Set the replica count (worker-group size) of the most recently
    /// added model.
    pub fn replicas(mut self, n: usize) -> Self {
        if n == 0 {
            self.errors.push("replicas(0) is not servable".into());
        }
        match self.models.last_mut() {
            Some(spec) => spec.replicas = n,
            None => self
                .errors
                .push("replicas(..) called before any model(..)".into()),
        }
        self
    }

    /// Set the backend factory of the most recently added model. The
    /// factory runs once per replica at [`build`](Self::build) time.
    pub fn backend<F>(mut self, factory: F) -> Self
    where
        F: FnMut(&Network, usize) -> Result<Box<dyn ExecutionBackend>, ServeError> + 'static,
    {
        match self.models.last_mut() {
            Some(spec) => spec.factory = Some(Box::new(factory)),
            None => self
                .errors
                .push("backend(..) called before any model(..)".into()),
        }
        self
    }

    /// Engine-wide dynamic-batching policy (validated at build).
    pub fn batch_policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Engine-wide worker-selection policy within each model's group.
    pub fn route_policy(mut self, route: RoutePolicy) -> Self {
        self.route = route;
        self
    }

    /// Engine-wide retry / circuit-breaker policy applied by each
    /// model's router (validated at build). Defaults to
    /// [`RetryPolicy::default`] — up to 3 attempts per request;
    /// [`RetryPolicy::none`] disables re-submission while keeping
    /// per-replica health tracking.
    pub fn retry_policy(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// Engine-wide kernel-parallelism budget handed to every backend.
    pub fn parallelism(mut self, parallelism: Parallelism) -> Self {
        self.parallelism = parallelism;
        self
    }

    /// Bound each worker's in-flight queue: once a worker holds this
    /// many admitted requests, further submissions to it fail fast
    /// with [`ServeError::Overloaded`] instead of growing the queue.
    /// Zero is rejected at [`build`](Self::build).
    pub fn queue_capacity(mut self, capacity: usize) -> Self {
        if capacity == 0 {
            self.errors
                .push("queue_capacity(0) admits no requests at all".into());
        }
        self.queue_capacity = Some(capacity);
        self
    }

    /// Clamp every worker's dynamic batch to the kernel pool's row
    /// budget (see
    /// [`ServerConfig::pool_sized_batches`](super::server::ServerConfig::pool_sized_batches)).
    pub fn pool_sized_batches(mut self, on: bool) -> Self {
        self.pool_sized_batches = on;
        self
    }

    /// Validate the whole configuration and start every worker group.
    pub fn build(self) -> Result<Engine, ServeError> {
        if !self.errors.is_empty() {
            return Err(ServeError::InvalidConfig(self.errors.join("; ")));
        }
        if self.models.is_empty() {
            return Err(ServeError::InvalidConfig(
                "engine needs at least one model(..)".into(),
            ));
        }
        self.policy.validate()?;
        let config = ServerConfig {
            policy: self.policy,
            parallelism: self.parallelism,
            queue_capacity: self.queue_capacity,
            pool_sized_batches: self.pool_sized_batches,
        };
        let mut groups = BTreeMap::new();
        for mut spec in self.models {
            spec.net.config.validate().map_err(|e| {
                ServeError::InvalidConfig(format!("model '{}': {e:#}", spec.name))
            })?;
            let input_width = spec.net.config.input_width();
            let num_classes = spec.net.config.num_classes();
            let backends = (0..spec.replicas)
                .map(|i| match &mut spec.factory {
                    Some(f) => f(&spec.net, i),
                    None => Ok(ReferenceBackend::boxed(spec.net.clone())),
                })
                .collect::<Result<Vec<_>, ServeError>>()?;
            // A factory may hand back any engine; when it declares its
            // shape, it must agree with the registered model's config —
            // caught here, once, instead of as per-request width errors
            // at serve time.
            for (i, b) in backends.iter().enumerate() {
                if let Some(w) = b.input_width() {
                    if w != input_width {
                        return Err(ServeError::InvalidConfig(format!(
                            "model '{}' replica {i}: backend '{}' expects {w}-wide input, \
                             model config says {input_width}",
                            spec.name,
                            b.tag()
                        )));
                    }
                }
                if let Some(c) = b.num_classes() {
                    if c != num_classes {
                        return Err(ServeError::InvalidConfig(format!(
                            "model '{}' replica {i}: backend '{}' emits {c} classes, \
                             model config says {num_classes}",
                            spec.name,
                            b.tag()
                        )));
                    }
                }
            }
            let router = Router::start_with_retry(backends, config, self.route, self.retry)?;
            groups.insert(
                spec.name,
                ModelGroup {
                    router,
                    input_width,
                    num_classes,
                },
            );
        }
        Ok(Engine { groups })
    }
}

struct ModelGroup {
    router: Router,
    input_width: usize,
    num_classes: usize,
}

/// A running multi-model inference engine.
pub struct Engine {
    groups: BTreeMap<String, ModelGroup>,
}

impl Engine {
    /// Start building an engine.
    pub fn builder() -> EngineBuilder {
        EngineBuilder::new()
    }

    /// Registered model names (sorted).
    pub fn models(&self) -> Vec<&str> {
        self.groups.keys().map(|s| s.as_str()).collect()
    }

    /// (input width, class count) of a model.
    pub fn model_shape(&self, model: &str) -> Result<(usize, usize), ServeError> {
        let g = self.group(model)?;
        Ok((g.input_width, g.num_classes))
    }

    /// Replica count of a model's worker group.
    pub fn replicas(&self, model: &str) -> Result<usize, ServeError> {
        Ok(self.group(model)?.router.num_workers())
    }

    fn group(&self, model: &str) -> Result<&ModelGroup, ServeError> {
        self.groups.get(model).ok_or_else(|| ServeError::UnknownModel {
            name: model.to_string(),
            available: self.groups.keys().cloned().collect(),
        })
    }

    /// Submit to a named model with explicit QoS options; the request
    /// resolves through the returned [`RoutedTicket`] (which
    /// transparently retries failed attempts on other replicas under
    /// the engine's [`RetryPolicy`]). Unknown models, width
    /// mismatches, admission overflow ([`ServeError::Overloaded`]
    /// after every replica was tried), and a draining engine
    /// ([`ServeError::ShuttingDown`]) are rejected here, synchronously.
    pub fn submit_with(
        &self,
        model: &str,
        features: Vec<f32>,
        opts: SubmitOptions,
    ) -> Result<RoutedTicket<'_>, ServeError> {
        let group = self.group(model)?;
        if features.is_empty() {
            return Err(ServeError::EmptyRequest);
        }
        if features.len() != group.input_width {
            return Err(ServeError::WidthMismatch {
                expected: group.input_width,
                got: features.len(),
            });
        }
        let (_, ticket) = group.router.submit_with(features, opts)?;
        Ok(ticket)
    }

    /// Submit to a named model with default options (no deadline,
    /// interactive priority).
    pub fn submit(&self, model: &str, features: Vec<f32>) -> Result<RoutedTicket<'_>, ServeError> {
        self.submit_with(model, features, SubmitOptions::default())
    }

    /// Submit to a named model and wait (convenience).
    pub fn infer(&self, model: &str, features: Vec<f32>) -> Result<InferenceResponse, ServeError> {
        self.submit(model, features)?.wait()
    }

    /// Live per-replica metrics of one model's worker group.
    pub fn metrics(&self, model: &str) -> Result<Vec<MetricsSnapshot>, ServeError> {
        Ok(self.group(model)?.router.metrics())
    }

    /// Per-replica circuit-breaker states of one model's worker group.
    pub fn health(&self, model: &str) -> Result<Vec<HealthState>, ServeError> {
        Ok(self.group(model)?.router.health())
    }

    /// Close admission on every model's worker group: subsequent
    /// submissions fail fast with [`ServeError::ShuttingDown`] while
    /// every already-admitted request still resolves with its typed
    /// outcome. Idempotent; [`shutdown`](Self::shutdown) implies it.
    pub fn begin_drain(&self) {
        for g in self.groups.values() {
            g.router.begin_drain();
        }
    }

    /// Stop every worker group gracefully — drain admission, flush
    /// queued work, join workers — returning per-model, per-replica
    /// final metrics.
    pub fn shutdown(self) -> BTreeMap<String, Vec<MetricsSnapshot>> {
        self.groups
            .into_iter()
            .map(|(name, g)| (name, g.router.shutdown()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{NetworkConfig, Precision};

    fn net(sizes: &[usize], seed: u64) -> Network {
        Network::random(&NetworkConfig::uniform(sizes, Precision::Bf16), seed)
    }

    #[test]
    fn builder_defaults_one_reference_replica() {
        let engine = Engine::builder()
            .model("m", net(&[8, 6, 3], 1))
            .build()
            .unwrap();
        assert_eq!(engine.models(), vec!["m"]);
        assert_eq!(engine.replicas("m").unwrap(), 1);
        assert_eq!(engine.model_shape("m").unwrap(), (8, 3));
        let resp = engine.infer("m", vec![0.5; 8]).unwrap();
        assert_eq!(resp.logits.len(), 3);
        engine.shutdown();
    }

    #[test]
    fn knobs_before_model_are_config_errors() {
        let err = Engine::builder()
            .replicas(2)
            .model("m", net(&[4, 2], 1))
            .build()
            .err()
            .expect("replicas before model must fail");
        assert!(matches!(err, ServeError::InvalidConfig(_)), "{err}");
        let err = Engine::builder()
            .backend(|n, _| Ok(ReferenceBackend::boxed(n.clone())))
            .build()
            .err()
            .expect("backend before model must fail");
        assert!(matches!(err, ServeError::InvalidConfig(_)), "{err}");
    }

    #[test]
    fn duplicate_and_missing_models_rejected() {
        let err = Engine::builder()
            .model("m", net(&[4, 2], 1))
            .model("m", net(&[4, 2], 2))
            .build()
            .err()
            .expect("duplicate model must fail");
        assert!(err.to_string().contains("duplicate"), "{err}");
        assert!(matches!(
            Engine::builder().build().err().unwrap(),
            ServeError::InvalidConfig(_)
        ));
    }

    #[test]
    fn zero_replicas_rejected() {
        let err = Engine::builder()
            .model("m", net(&[4, 2], 1))
            .replicas(0)
            .build()
            .err()
            .expect("replicas(0) must fail");
        assert!(matches!(err, ServeError::InvalidConfig(_)), "{err}");
    }

    #[test]
    fn unknown_model_lists_available() {
        let engine = Engine::builder()
            .model("a", net(&[4, 2], 1))
            .model("b", net(&[6, 2], 2))
            .build()
            .unwrap();
        let err = engine.submit("c", vec![0.0; 4]).unwrap_err();
        assert_eq!(
            err,
            ServeError::UnknownModel {
                name: "c".into(),
                available: vec!["a".into(), "b".into()],
            }
        );
        engine.shutdown();
    }

    #[test]
    fn per_model_width_validation() {
        let engine = Engine::builder()
            .model("wide", net(&[16, 4], 1))
            .model("narrow", net(&[4, 2], 2))
            .build()
            .unwrap();
        // The same feature vector is valid for one model, typed-error
        // for the other.
        let four = vec![0.1; 4];
        assert!(engine.infer("narrow", four.clone()).is_ok());
        assert_eq!(
            engine.submit("wide", four).unwrap_err(),
            ServeError::WidthMismatch {
                expected: 16,
                got: 4
            }
        );
        assert_eq!(
            engine.submit("narrow", vec![]).unwrap_err(),
            ServeError::EmptyRequest
        );
        engine.shutdown();
    }

    #[test]
    fn factory_shape_disagreement_caught_at_build() {
        // The factory ignores the registered 8-wide model and builds a
        // 4-wide backend: a config error at build(), not per-request
        // width errors at serve time.
        let err = Engine::builder()
            .model("m", net(&[8, 3], 1))
            .backend(|_n, _i| Ok(ReferenceBackend::boxed(net(&[4, 2], 2))))
            .build()
            .err()
            .expect("shape disagreement must fail at build");
        assert!(matches!(err, ServeError::InvalidConfig(_)), "{err}");
        assert!(err.to_string().contains("4-wide"), "{err}");
    }

    #[test]
    fn zero_queue_capacity_rejected_at_build() {
        let err = Engine::builder()
            .model("m", net(&[4, 2], 1))
            .queue_capacity(0)
            .build()
            .err()
            .expect("queue_capacity(0) must fail");
        assert!(matches!(err, ServeError::InvalidConfig(_)), "{err}");
    }

    #[test]
    fn already_expired_deadline_is_a_typed_error_not_a_served_request() {
        use crate::coordinator::request::SubmitOptions;
        use std::time::Duration;
        let engine = Engine::builder()
            .model("m", net(&[8, 3], 5))
            .queue_capacity(64)
            .build()
            .unwrap();
        let t = engine
            .submit_with(
                "m",
                vec![0.1; 8],
                SubmitOptions::default().with_deadline(Duration::ZERO),
            )
            .unwrap();
        match t.wait().unwrap_err() {
            ServeError::DeadlineExceeded { .. } => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        // Live traffic unaffected.
        assert_eq!(engine.infer("m", vec![0.1; 8]).unwrap().logits.len(), 3);
        let totals = engine.shutdown();
        assert_eq!(totals["m"][0].expired, 1);
        assert_eq!(totals["m"][0].requests, 1);
    }

    #[test]
    fn engine_drain_closes_admission_but_flushes() {
        let engine = Engine::builder()
            .model("m", net(&[8, 3], 1))
            .build()
            .unwrap();
        let queued = engine.submit("m", vec![0.1; 8]).unwrap();
        engine.begin_drain();
        assert_eq!(
            engine.submit("m", vec![0.1; 8]).unwrap_err(),
            ServeError::ShuttingDown
        );
        assert!(queued.wait().is_ok(), "queued work flushes during drain");
        assert_eq!(engine.health("m").unwrap(), vec![HealthState::Closed]);
        let totals = engine.shutdown();
        assert_eq!(totals["m"][0].requests, 1);
    }

    #[test]
    fn engine_retries_a_faulty_replica_transparently() {
        use crate::coordinator::fault::{FaultInjectingBackend, FaultSpec};
        // Replica 0 always errors; replica 1 is healthy. The engine's
        // default retry policy hides the faults from callers.
        let engine = Engine::builder()
            .model("m", net(&[8, 3], 1))
            .replicas(2)
            .backend(|n, i| {
                let inner = ReferenceBackend::boxed(n.clone());
                Ok(if i == 0 {
                    FaultInjectingBackend::boxed(inner, FaultSpec::errors(1.0, 7))
                } else {
                    inner
                })
            })
            .build()
            .unwrap();
        let mut retried = 0u32;
        for _ in 0..6 {
            retried += engine.infer("m", vec![0.2; 8]).unwrap().retries;
        }
        assert!(retried >= 1, "some requests must have been retried");
        let totals = engine.shutdown();
        assert_eq!(totals["m"][1].requests, 6, "all work ends on the healthy replica");
        assert_eq!(totals["m"][0].retries, totals["m"][0].failures);
    }

    #[test]
    fn invalid_retry_policy_rejected_at_build() {
        let err = Engine::builder()
            .model("m", net(&[4, 2], 1))
            .retry_policy(RetryPolicy {
                max_attempts: 0,
                ..RetryPolicy::default()
            })
            .build()
            .err()
            .expect("max_attempts 0 must fail at build");
        assert!(matches!(err, ServeError::InvalidConfig(_)), "{err}");
    }

    #[test]
    fn factory_runs_once_per_replica() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let calls = Arc::new(AtomicUsize::new(0));
        let calls_f = Arc::clone(&calls);
        let engine = Engine::builder()
            .model("m", net(&[8, 3], 1))
            .replicas(3)
            .backend(move |n, i| {
                assert!(i < 3);
                calls_f.fetch_add(1, Ordering::Relaxed);
                Ok(ReferenceBackend::boxed(n.clone()))
            })
            .build()
            .unwrap();
        assert_eq!(calls.load(Ordering::Relaxed), 3);
        assert_eq!(engine.replicas("m").unwrap(), 3);
        engine.shutdown();
    }
}
