//! The serving loop: a worker thread owning a boxed
//! [`ExecutionBackend`], fed through the QoS-aware dynamic batcher.
//!
//! The queue is a real admission point. [`Server::submit_with`]
//! validates the request *and* admits it against
//! [`ServerConfig::queue_capacity`]: when the bound is reached the
//! caller gets a synchronous [`ServeError::Overloaded`] instead of an
//! unbounded queue quietly growing — memory and tail latency stay
//! bounded by construction. Admitted requests resolve through an owned
//! [`Ticket`]; the batcher drops expired requests before they reach
//! the backend and discards cancelled ones.
//!
//! Failure stays typed end to end: malformed requests are rejected at
//! submit with a [`ServeError`] (they never reach the worker thread),
//! and backend failures arrive on the ticket as the `Err` arm of a
//! [`ServeResult`](super::error::ServeResult).

use std::sync::mpsc::{channel, Sender};
use std::time::Instant;

use anyhow::ensure;

use super::backend::ExecutionBackend;
use super::batcher::{BatchPolicy, BatchQueue};
use super::error::ServeError;
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{InferenceRequest, InferenceResponse, Priority, SubmitOptions, Ticket};
use crate::bf16::Matrix;
use crate::nn::metrics::argmax;
use crate::util::par::Parallelism;
use crate::util::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use crate::util::sync::{thread, Arc};

/// Rows of one dynamic batch each kernel worker can chew before extra
/// rows stop buying parallelism and only add queue latency — the
/// pool-aware batch ceiling is `workers × ROWS_PER_WORKER` (see
/// [`ServerConfig::pool_sized_batches`]).
pub const ROWS_PER_WORKER: usize = 32;

/// The in-flight count at which Bulk submissions stop being admitted:
/// capacity minus a reserve of one eighth (at least one slot) kept for
/// Interactive traffic. A capacity of 1 has no slot to spare — there
/// the single slot stays first-come-first-served.
fn bulk_admission_limit(capacity: usize) -> usize {
    if capacity <= 1 {
        return capacity;
    }
    capacity - (capacity / 8).clamp(1, capacity - 1)
}

/// Server configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Batching policy (validated by [`Server::start`]).
    pub policy: BatchPolicy,
    /// Kernel-parallelism budget handed to the backend for every batch
    /// (auto-sized to the host by default). A dynamic batch closed by
    /// the batcher fans its matmuls out across this many cores; logits
    /// are bit-identical at any worker count. The budget dispatches to
    /// the process-wide persistent worker pool, which [`Server::start`]
    /// constructs eagerly — so no request, not even the first, pays
    /// thread-spawn cost.
    pub parallelism: Parallelism,
    /// Bound on in-flight requests (admitted but not yet resolved,
    /// cancelled, or expired). `None` (default) keeps the historical
    /// unbounded queue; `Some(n)` makes `submit` return
    /// [`ServeError::Overloaded`] once `n` requests are in flight.
    /// `Some(0)` is rejected at [`Server::start`]. Admission is
    /// priority-aware: the top eighth of the capacity (at least one
    /// slot, for capacities ≥ 2) is reserved for
    /// [`Priority::Interactive`] traffic, so queued bulk backfill can
    /// fill the batcher but never starve interactive *admission*.
    pub queue_capacity: Option<usize>,
    /// Clamp the dynamic batch to the worker pool's budget
    /// (`parallelism` workers × [`ROWS_PER_WORKER`] rows): rows beyond
    /// what the pool can process concurrently only add queue latency
    /// for host-pool backends. Off by default — device-model backends
    /// (the simulator) amortize per-command overheads over *bigger*
    /// batches and run no host kernels, so the clamp would cost them
    /// modeled throughput.
    pub pool_sized_batches: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            policy: BatchPolicy::default(),
            parallelism: Parallelism::default(),
            queue_capacity: None,
            pool_sized_batches: false,
        }
    }
}

/// A running inference server over one backend.
pub struct Server {
    tx: Option<Sender<InferenceRequest>>,
    handle: Option<thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    /// In-flight gauge: incremented at admission, decremented exactly
    /// once per request by its lifecycle (resolution, cancellation,
    /// expiry, or teardown).
    depth: Arc<AtomicUsize>,
    queue_capacity: Option<usize>,
    /// Set by [`begin_drain`](Self::begin_drain): admission is closed
    /// (`submit` returns [`ServeError::ShuttingDown`]) while the worker
    /// keeps flushing already-queued work.
    draining: Arc<AtomicBool>,
    /// Input width every request must match. `0` means "not yet known":
    /// the backend declared no width, so the first accepted request
    /// pins it (batches must be rectangular). Shared with the worker,
    /// which *unpins* the width again if the backend rejects a batch
    /// before any batch of that width ever succeeded — a mis-sized
    /// first guess must not lock out correctly-sized traffic forever,
    /// while a once-confirmed width survives transient backend faults.
    expected_width: Arc<AtomicUsize>,
}

impl Server {
    /// Start the worker thread over any backend. Validates the batch
    /// policy and queue capacity, clamps the policy to the backend's
    /// `max_batch` (and, when
    /// [`ServerConfig::pool_sized_batches`] is on, to the worker
    /// pool's row budget), runs the backend's
    /// [`warm`](ExecutionBackend::warm) hook, and warms the
    /// process-wide kernel worker pool (a no-op for serial budgets and
    /// on every call after the first), so batch dispatch never spawns.
    pub fn start(
        mut backend: Box<dyn ExecutionBackend>,
        config: ServerConfig,
    ) -> Result<Self, ServeError> {
        config.policy.validate()?;
        if config.queue_capacity == Some(0) {
            return Err(ServeError::InvalidConfig(
                "queue_capacity of 0 admits no requests at all".into(),
            ));
        }
        let mut policy = config.policy;
        if let Some(cap) = backend.max_batch() {
            if cap == 0 {
                return Err(ServeError::InvalidConfig(format!(
                    "backend '{}' reports max_batch == 0",
                    backend.tag()
                )));
            }
            // Shape-specialized backends cap the dynamic batch.
            policy.max_batch = policy.max_batch.min(cap);
        }
        if config.pool_sized_batches {
            // The pool-aware batcher (ROADMAP follow-on): don't hold a
            // batch open for more rows than the kernel pool can chew
            // concurrently.
            let workers = config.parallelism.max_workers().max(1);
            policy.max_batch = policy.max_batch.min(workers * ROWS_PER_WORKER).max(1);
        }
        let declared_width = backend.input_width();
        let expected_width = Arc::new(AtomicUsize::new(declared_width.unwrap_or(0)));
        // Only a *pinned* (guessed-from-traffic) width may be reset by
        // the worker on backend failure; a declared width is authoritative.
        let unpin_on_failure = if declared_width.is_none() {
            Some(Arc::clone(&expected_width))
        } else {
            None
        };
        let expected_worker = Arc::clone(&expected_width);
        let declared_classes = backend.num_classes();
        backend.warm();
        config.parallelism.warm_pool();
        let tag = backend.tag().to_string();
        let (tx, rx) = channel::<InferenceRequest>();
        let metrics = Arc::new(Metrics::new());
        let metrics_worker = Arc::clone(&metrics);
        let parallelism = config.parallelism;
        let handle = thread::spawn(move || {
            let mut queue = BatchQueue::new(rx);
            // Once any batch of the pinned width has succeeded, the pin
            // is confirmed and never reset: a later transient backend
            // fault must not let a stray mis-sized request steal it.
            let mut width_confirmed = false;
            while let Some(batch) = policy.next_batch(&mut queue, &metrics_worker) {
                let closed_at = Instant::now();
                // `submit` rejects width mismatches, so batches are
                // normally rectangular — but when an undeclared width is
                // unpinned after a failure and re-pinned by newer traffic,
                // leftover queued requests of the old width can share a
                // batch with the new one. Partition against the *current*
                // pin (falling back to the batch head when unpinned)
                // instead of trusting the invariant: stale-width requests
                // get a typed error, never a `copy_from_slice` panic.
                let width = match expected_worker.load(Ordering::Relaxed) {
                    0 => batch[0].features.len(),
                    w => w,
                };
                // Fast path: submit-side validation makes mismatches a
                // rare post-unpin edge, so don't pay partition's moves
                // and allocations on every batch.
                let batch = if batch.iter().all(|req| req.features.len() == width) {
                    batch
                } else {
                    let (keep, mismatched): (Vec<_>, Vec<_>) = batch
                        .into_iter()
                        .partition(|req| req.features.len() == width);
                    for req in mismatched {
                        metrics_worker.record_failures(1);
                        let got = req.features.len();
                        req.resolve(Err(ServeError::WidthMismatch {
                            expected: width,
                            got,
                        }));
                    }
                    keep
                };
                if batch.is_empty() {
                    continue;
                }
                let rows = batch.len();
                let mut features = Matrix::zeros(rows, width);
                for (r, req) in batch.iter().enumerate() {
                    features.row_mut(r).copy_from_slice(&req.features);
                }
                let t0 = Instant::now();
                // Shape-check the backend's answer: a misbehaving
                // third-party engine must become a typed error for this
                // batch, not an out-of-bounds panic that kills the
                // worker. The call itself runs under `catch_unwind`: a
                // panicking backend (driver bug, injected chaos) is
                // contained to this batch — the requests get a typed
                // [`ServeError::Backend`] and the worker thread lives
                // on to serve the next batch, instead of dying silently
                // with the whole replica. `AssertUnwindSafe` is sound
                // here because on unwind the backend is only ever
                // touched again through `run_batch_with` (whose
                // implementations own their state) and the rest of the
                // captured state (`features`, metrics) is not mutated
                // mid-call.
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    backend.run_batch_with(&features, parallelism)
                }))
                .unwrap_or_else(|payload| {
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "opaque panic payload".into());
                    Err(anyhow::anyhow!("backend panicked: {msg}"))
                })
                .and_then(|out| {
                    ensure!(
                        out.logits.rows == rows && out.logits.cols > 0,
                        "backend returned {}x{} logits for a {rows}-row batch",
                        out.logits.rows,
                        out.logits.cols
                    );
                    if let Some(classes) = declared_classes {
                        ensure!(
                            out.logits.cols == classes,
                            "backend returned {} logit columns, declared {classes}",
                            out.logits.cols
                        );
                    }
                    Ok(out)
                });
                let out = match result {
                    Ok(out) => out,
                    Err(e) => {
                        // Also log server-side: a client that dropped its
                        // ticket must not make the fault invisible.
                        eprintln!("[beanna::serve] backend '{tag}' error: {e:#}");
                        let err = ServeError::Backend {
                            backend: tag.clone(),
                            message: format!("{e:#}"),
                        };
                        metrics_worker.record_failures(rows);
                        // Wire faults move the transport gauge exactly
                        // when batches fail — poll it on this path too,
                        // so a dead worker's errors are visible without
                        // waiting for the next success.
                        if let Some(stats) = backend.transport_stats() {
                            metrics_worker.record_transport_stats(stats);
                        }
                        // An unconfirmed pin came from this (rejected)
                        // traffic's own guess — let the next request
                        // re-pin it. A confirmed width stays.
                        if !width_confirmed {
                            if let Some(pin) = &unpin_on_failure {
                                pin.store(0, Ordering::Relaxed);
                            }
                        }
                        for req in batch {
                            req.resolve(Err(err.clone()));
                        }
                        continue;
                    }
                };
                let compute_us = t0.elapsed().as_micros() as u64;
                let queue_us: Vec<u64> = batch
                    .iter()
                    .map(|r| closed_at.duration_since(r.enqueued_at).as_micros() as u64)
                    .collect();
                metrics_worker.record_batch(rows, &queue_us, compute_us, out.sim_cycles);
                // Multi-array backends report per-shard backlogs; keep
                // the latest gauge in the metrics.
                if let Some(depths) = backend.shard_depths() {
                    metrics_worker.record_shard_depths(depths);
                }
                // Remote backends report cumulative wire-health
                // counters; same latest-wins gauge treatment.
                if let Some(stats) = backend.transport_stats() {
                    metrics_worker.record_transport_stats(stats);
                }
                // Re-assert the width that actually succeeded: the pin
                // may have been cleared by an earlier failure and this
                // batch served via the head-width fallback, and a
                // confirmed width must really be the stored one.
                expected_worker.store(width, Ordering::Relaxed);
                width_confirmed = true;
                for (r, req) in batch.into_iter().enumerate() {
                    let logits = out.logits.row(r).to_vec();
                    let id = req.id;
                    req.resolve(Ok(InferenceResponse {
                        id,
                        prediction: argmax(&logits),
                        logits,
                        queue_us: queue_us[r],
                        compute_us,
                        batch_size: rows,
                        sim_cycles: out.sim_cycles,
                        retries: 0,
                    }));
                }
            }
        });
        Ok(Self {
            tx: Some(tx),
            handle: Some(handle),
            metrics,
            next_id: AtomicU64::new(0),
            depth: Arc::new(AtomicUsize::new(0)),
            queue_capacity: config.queue_capacity,
            draining: Arc::new(AtomicBool::new(false)),
            expected_width,
        })
    }

    /// Validate a request's feature width against the served model,
    /// pinning the width from the first request when the backend
    /// declared none.
    fn check_width(&self, got: usize) -> Result<(), ServeError> {
        if got == 0 {
            return Err(ServeError::EmptyRequest);
        }
        let expected = match self.expected_width.load(Ordering::Relaxed) {
            0 => match self
                .expected_width
                .compare_exchange(0, got, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => got,
                Err(winner) => winner,
            },
            w => w,
        };
        if got != expected {
            return Err(ServeError::WidthMismatch { expected, got });
        }
        Ok(())
    }

    /// Input width this server accepts, if already known.
    pub fn input_width(&self) -> Option<usize> {
        match self.expected_width.load(Ordering::Relaxed) {
            0 => None,
            w => Some(w),
        }
    }

    /// Requests currently in flight (admitted, not yet resolved,
    /// cancelled, or expired).
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Acquire)
    }

    /// Submit with explicit QoS options; the request resolves through
    /// the returned [`Ticket`]. Rejections are synchronous and typed:
    /// width mismatches ([`ServeError::WidthMismatch`]) and admission
    /// overflow ([`ServeError::Overloaded`]) never reach the worker
    /// thread.
    pub fn submit_with(
        &self,
        features: Vec<f32>,
        opts: SubmitOptions,
    ) -> Result<Ticket, ServeError> {
        if self.draining.load(Ordering::Acquire) {
            return Err(ServeError::ShuttingDown);
        }
        self.check_width(features.len())?;
        // Admission: claim a slot, give it back if over the bound. The
        // momentary overshoot of a losing racer is bounded by the
        // number of concurrent submitters and is always rolled back.
        // Bulk stops short of the full bound (see
        // [`ServerConfig::queue_capacity`]): without the headroom, a
        // backfill flood would hold every slot and interactive traffic
        // could never even be admitted for the batcher to prioritize.
        let prev = self.depth.fetch_add(1, Ordering::AcqRel);
        if let Some(cap) = self.queue_capacity {
            let limit = match opts.priority {
                Priority::Interactive => cap,
                Priority::Bulk => bulk_admission_limit(cap),
            };
            if prev >= limit {
                self.depth.fetch_sub(1, Ordering::AcqRel);
                self.metrics.record_rejected(1);
                return Err(ServeError::Overloaded {
                    depth: prev,
                    capacity: limit,
                });
            }
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (req, ticket) =
            InferenceRequest::create(id, features, opts, Arc::clone(&self.depth));
        // On either Stopped path the undelivered `req` is dropped,
        // which rolls the admission slot back.
        let tx = self.tx.as_ref().ok_or(ServeError::Stopped)?;
        tx.send(req).map_err(|_| ServeError::Stopped)?;
        Ok(ticket)
    }

    /// Submit with default options (no deadline, interactive
    /// priority); the response (or typed error) resolves through the
    /// returned [`Ticket`].
    pub fn submit(&self, features: Vec<f32>) -> Result<Ticket, ServeError> {
        self.submit_with(features, SubmitOptions::default())
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, features: Vec<f32>) -> Result<InferenceResponse, ServeError> {
        self.submit(features)?.wait()
    }

    /// Live metrics handle.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Shared handle to the live metrics registry (used by the router's
    /// load-aware policies without snapshot locking).
    pub fn metrics_handle(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Close admission without stopping the worker: subsequent
    /// `submit` calls fail fast with [`ServeError::ShuttingDown`],
    /// while every already-admitted request still resolves normally —
    /// served, expired, or cancelled, each with its typed outcome.
    /// Idempotent. [`shutdown`](Self::shutdown) implies it.
    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::Release);
    }

    /// True once [`begin_drain`](Self::begin_drain) (or `shutdown`)
    /// has closed admission.
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }

    /// Stop the server gracefully: close admission
    /// ([`begin_drain`](Self::begin_drain)), flush the queue (every
    /// queued request is served — or expired/cancelled with its typed
    /// error — before the worker exits), join the worker, and return
    /// the final metrics.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.begin_drain();
        self.tx.take(); // close the queue; worker flushes and exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        self.metrics.snapshot()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{BatchOutput, ReferenceBackend};
    use crate::coordinator::request::Priority;
    use crate::nn::{Network, NetworkConfig, Precision};
    use std::time::Duration;

    fn tiny_backend() -> Box<dyn ExecutionBackend> {
        ReferenceBackend::boxed(Network::random(
            &NetworkConfig {
                sizes: vec![784, 16, 10],
                precisions: vec![Precision::Bf16, Precision::Bf16],
                front: None,
            },
            1,
        ))
    }

    #[test]
    fn serves_single_requests() {
        let server = Server::start(tiny_backend(), ServerConfig::default()).unwrap();
        let resp = server.infer(vec![0.5; 784]).unwrap();
        assert_eq!(resp.logits.len(), 10);
        assert!(resp.prediction < 10);
        let m = server.shutdown();
        assert_eq!(m.requests, 1);
        assert_eq!(m.batches, 1);
        assert_eq!(m.failures, 0);
        assert_eq!(m.rejected, 0);
    }

    #[test]
    fn batches_concurrent_requests() {
        let server = Server::start(
            tiny_backend(),
            ServerConfig {
                policy: BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_millis(30),
                },
                ..Default::default()
            },
        )
        .unwrap();
        let tickets: Vec<_> = (0..8)
            .map(|i| server.submit(vec![i as f32 / 8.0; 784]).unwrap())
            .collect();
        let resps: Vec<_> = tickets
            .into_iter()
            .map(|t| t.wait().unwrap())
            .collect();
        assert!(resps.iter().all(|r| r.logits.len() == 10));
        // At least some requests must have shared a batch.
        let max_batch_seen = resps.iter().map(|r| r.batch_size).max().unwrap();
        assert!(max_batch_seen >= 2, "no batching happened");
        let m = server.shutdown();
        assert_eq!(m.requests, 8);
        assert!(m.batches < 8);
    }

    #[test]
    fn deterministic_predictions_match_reference() {
        let net = Network::random(
            &NetworkConfig {
                sizes: vec![784, 16, 10],
                precisions: vec![Precision::Bf16, Precision::Bf16],
                front: None,
            },
            1,
        );
        let image = vec![0.25; 784];
        let direct = net
            .predict(&Matrix::from_vec(1, 784, image.clone()).unwrap())
            .unwrap()[0];
        let server =
            Server::start(ReferenceBackend::boxed(net), ServerConfig::default()).unwrap();
        let resp = server.infer(image).unwrap();
        assert_eq!(resp.prediction, direct);
        server.shutdown();
    }

    #[test]
    fn shutdown_drains() {
        let server = Server::start(tiny_backend(), ServerConfig::default()).unwrap();
        let ticket = server.submit(vec![0.0; 784]).unwrap();
        let m = server.shutdown();
        // The queued request is served before the worker exits.
        assert_eq!(m.requests, 1);
        assert!(ticket.wait().is_ok());
    }

    #[test]
    fn width_mismatch_rejected_at_submit() {
        let server = Server::start(tiny_backend(), ServerConfig::default()).unwrap();
        assert_eq!(server.input_width(), Some(784));
        let err = server.submit(vec![0.1; 10]).unwrap_err();
        assert_eq!(
            err,
            ServeError::WidthMismatch {
                expected: 784,
                got: 10
            }
        );
        assert_eq!(server.submit(vec![]).unwrap_err(), ServeError::EmptyRequest);
        // Well-formed traffic still flows afterwards.
        assert_eq!(server.infer(vec![0.2; 784]).unwrap().logits.len(), 10);
        server.shutdown();
    }

    #[test]
    fn zero_max_batch_is_a_config_error() {
        let err = Server::start(
            tiny_backend(),
            ServerConfig {
                policy: BatchPolicy {
                    max_batch: 0,
                    max_wait: Duration::ZERO,
                },
                ..Default::default()
            },
        )
        .err()
        .expect("max_batch 0 must be rejected");
        assert!(matches!(err, ServeError::InvalidConfig(_)));
    }

    #[test]
    fn bulk_admission_reserve_math() {
        assert_eq!(bulk_admission_limit(1), 1, "no slot to spare");
        assert_eq!(bulk_admission_limit(2), 1);
        assert_eq!(bulk_admission_limit(8), 7);
        assert_eq!(bulk_admission_limit(32), 28);
        assert_eq!(bulk_admission_limit(1024), 896);
    }

    #[test]
    fn zero_queue_capacity_is_a_config_error() {
        let err = Server::start(
            tiny_backend(),
            ServerConfig {
                queue_capacity: Some(0),
                ..Default::default()
            },
        )
        .err()
        .expect("queue_capacity 0 must be rejected");
        assert!(matches!(err, ServeError::InvalidConfig(_)), "{err}");
    }

    #[test]
    fn queue_depth_tracks_in_flight_and_drains() {
        let server = Server::start(
            tiny_backend(),
            ServerConfig {
                queue_capacity: Some(16),
                ..Default::default()
            },
        )
        .unwrap();
        let tickets: Vec<_> = (0..4)
            .map(|_| server.submit(vec![0.1; 784]).unwrap())
            .collect();
        assert!(server.queue_depth() <= 4);
        for t in tickets {
            t.wait().unwrap();
        }
        // All resolved: every admission slot is back.
        assert_eq!(server.queue_depth(), 0);
        server.shutdown();
    }

    #[test]
    fn submit_with_deadline_and_priority_round_trips() {
        let server = Server::start(tiny_backend(), ServerConfig::default()).unwrap();
        let t = server
            .submit_with(
                vec![0.2; 784],
                SubmitOptions {
                    deadline: Some(Duration::from_secs(30)),
                    priority: Priority::Bulk,
                },
            )
            .unwrap();
        assert!(t.wait().is_ok(), "a generous deadline must not expire");
        server.shutdown();
    }

    #[test]
    fn pool_sized_batches_clamp_to_the_worker_budget() {
        // Two fixed workers → the dynamic batch must never exceed
        // 2 × ROWS_PER_WORKER even though the policy asks for 4096 and
        // the queue is deep.
        let server = Server::start(
            tiny_backend(),
            ServerConfig {
                policy: BatchPolicy {
                    max_batch: 4096,
                    max_wait: Duration::from_millis(40),
                },
                parallelism: Parallelism::fixed(2),
                pool_sized_batches: true,
                ..Default::default()
            },
        )
        .unwrap();
        let tickets: Vec<_> = (0..(2 * ROWS_PER_WORKER + 8))
            .map(|_| server.submit(vec![0.3; 784]).unwrap())
            .collect();
        let max_seen = tickets
            .into_iter()
            .map(|t| t.wait().unwrap().batch_size)
            .max()
            .unwrap();
        assert!(
            max_seen <= 2 * ROWS_PER_WORKER,
            "batch of {max_seen} exceeds the pool budget"
        );
        server.shutdown();
    }

    #[test]
    fn pinned_width_unpins_after_backend_rejects_it() {
        // Declares no width, but only actually accepts 64-wide rows.
        struct Picky;
        impl ExecutionBackend for Picky {
            fn run_batch_with(
                &mut self,
                batch: &Matrix,
                _par: Parallelism,
            ) -> anyhow::Result<BatchOutput> {
                anyhow::ensure!(batch.cols == 64, "device wants 64-wide rows");
                Ok(BatchOutput {
                    logits: Matrix::zeros(batch.rows, 2),
                    sim_cycles: None,
                })
            }
            fn tag(&self) -> &str {
                "picky"
            }
        }
        let server = Server::start(
            Box::new(Picky),
            ServerConfig {
                policy: BatchPolicy::unbatched(),
                ..Default::default()
            },
        )
        .unwrap();
        // A wrong first guess pins 100 and fails on the backend…
        let err = server.infer(vec![0.0; 100]).unwrap_err();
        assert!(matches!(err, ServeError::Backend { .. }), "{err}");
        // …but must not lock out correctly-sized traffic afterwards.
        let ok = server.infer(vec![0.0; 64]).unwrap();
        assert_eq!(ok.logits.len(), 2);
        assert_eq!(server.input_width(), Some(64));
        server.shutdown();
    }

    #[test]
    fn width_served_after_unpin_is_stored_and_cannot_be_stolen() {
        // Accepts any width but faults on its first batch; declares none.
        struct FlakyEcho {
            failed: bool,
        }
        impl ExecutionBackend for FlakyEcho {
            fn run_batch_with(
                &mut self,
                batch: &Matrix,
                _par: Parallelism,
            ) -> anyhow::Result<BatchOutput> {
                if !self.failed {
                    self.failed = true;
                    anyhow::bail!("transient hiccup");
                }
                Ok(BatchOutput {
                    logits: Matrix::zeros(batch.rows, 1),
                    sim_cycles: None,
                })
            }
            fn tag(&self) -> &str {
                "flaky-echo"
            }
        }
        let server = Server::start(
            Box::new(FlakyEcho { failed: false }),
            ServerConfig {
                policy: BatchPolicy::unbatched(),
                ..Default::default()
            },
        )
        .unwrap();
        let t_a = server.submit(vec![0.0; 100]).unwrap(); // pins 100
        let t_b = server.submit(vec![0.0; 100]).unwrap();
        assert!(t_a.wait().is_err()); // fault → width unpinned
        assert!(t_b.wait().is_ok()); // served via head fallback
        // The width that actually served is stored back and confirmed —
        // a stray mis-sized request cannot steal the pin any more.
        assert_eq!(server.input_width(), Some(100));
        assert_eq!(
            server.submit(vec![0.0; 77]).unwrap_err(),
            ServeError::WidthMismatch {
                expected: 100,
                got: 77
            }
        );
        server.shutdown();
    }

    #[test]
    fn begin_drain_closes_admission_but_flushes_queued_work() {
        let server = Server::start(tiny_backend(), ServerConfig::default()).unwrap();
        let queued = server.submit(vec![0.4; 784]).unwrap();
        server.begin_drain();
        assert!(server.is_draining());
        // New work is refused with the drain-specific error…
        assert_eq!(
            server.submit(vec![0.4; 784]).unwrap_err(),
            ServeError::ShuttingDown
        );
        // …while already-admitted work still resolves normally.
        assert!(queued.wait().is_ok());
        let m = server.shutdown();
        assert_eq!(m.requests, 1);
        assert_eq!(m.rejected, 0, "drain refusals are not admission rejections");
    }

    #[test]
    fn worker_survives_a_panicking_backend() {
        // Panics on its first batch, then behaves.
        struct Grenade {
            armed: bool,
        }
        impl ExecutionBackend for Grenade {
            fn run_batch_with(
                &mut self,
                batch: &Matrix,
                _par: Parallelism,
            ) -> anyhow::Result<BatchOutput> {
                if self.armed {
                    self.armed = false;
                    panic!("kaboom");
                }
                Ok(BatchOutput {
                    logits: Matrix::zeros(batch.rows, 2),
                    sim_cycles: None,
                })
            }
            fn tag(&self) -> &str {
                "grenade"
            }
            fn input_width(&self) -> Option<usize> {
                Some(4)
            }
        }
        let server = Server::start(
            Box::new(Grenade { armed: true }),
            ServerConfig {
                policy: BatchPolicy::unbatched(),
                ..Default::default()
            },
        )
        .unwrap();
        // The panic surfaces as a typed Backend error on the ticket…
        match server.infer(vec![0.1; 4]).unwrap_err() {
            ServeError::Backend { backend, message } => {
                assert_eq!(backend, "grenade");
                assert!(message.contains("panicked"), "{message}");
                assert!(message.contains("kaboom"), "{message}");
            }
            other => panic!("expected Backend error, got {other:?}"),
        }
        // …and the worker thread is alive to serve the next request.
        assert_eq!(server.infer(vec![0.1; 4]).unwrap().logits.len(), 2);
        let m = server.shutdown();
        assert_eq!(m.failures, 1);
        assert_eq!(m.requests, 1);
    }

    #[test]
    fn width_pinned_from_first_request_when_backend_is_silent() {
        // A width-agnostic backend: echoes row-sums, any width.
        struct Echo;
        impl ExecutionBackend for Echo {
            fn run_batch_with(
                &mut self,
                batch: &Matrix,
                _par: Parallelism,
            ) -> anyhow::Result<BatchOutput> {
                let mut logits = Matrix::zeros(batch.rows, 1);
                for r in 0..batch.rows {
                    logits.row_mut(r)[0] = batch.row(r).iter().sum();
                }
                Ok(BatchOutput {
                    logits,
                    sim_cycles: None,
                })
            }
            fn tag(&self) -> &str {
                "echo"
            }
        }
        let server = Server::start(Box::new(Echo), ServerConfig::default()).unwrap();
        assert_eq!(server.input_width(), None);
        assert_eq!(server.infer(vec![1.0; 3]).unwrap().logits, vec![3.0]);
        assert_eq!(server.input_width(), Some(3));
        // Pinned: a different width is now a typed error.
        assert_eq!(
            server.submit(vec![0.0; 4]).unwrap_err(),
            ServeError::WidthMismatch {
                expected: 3,
                got: 4
            }
        );
        server.shutdown();
    }
}
