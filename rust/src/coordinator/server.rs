//! The serving loop: a worker thread owning a boxed
//! [`ExecutionBackend`], fed through the dynamic batcher.
//!
//! Failure is typed end to end: malformed requests are rejected at
//! [`Server::submit`] with a [`ServeError`] (they never reach the
//! worker thread), and backend failures arrive on the response channel
//! as the `Err` arm of a [`ServeResult`].

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::ensure;

use super::backend::ExecutionBackend;
use super::batcher::BatchPolicy;
use super::error::{ServeError, ServeResult};
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{InferenceRequest, InferenceResponse};
use crate::bf16::Matrix;
use crate::nn::metrics::argmax;
use crate::util::par::Parallelism;

/// Server configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Batching policy (validated by [`Server::start`]).
    pub policy: BatchPolicy,
    /// Kernel-parallelism budget handed to the backend for every batch
    /// (auto-sized to the host by default). A dynamic batch closed by
    /// the batcher fans its matmuls out across this many cores; logits
    /// are bit-identical at any worker count. The budget dispatches to
    /// the process-wide persistent worker pool, which [`Server::start`]
    /// constructs eagerly — so no request, not even the first, pays
    /// thread-spawn cost.
    pub parallelism: Parallelism,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            policy: BatchPolicy::default(),
            parallelism: Parallelism::default(),
        }
    }
}

/// A running inference server over one backend.
pub struct Server {
    tx: Option<Sender<InferenceRequest>>,
    handle: Option<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    /// Input width every request must match. `0` means "not yet known":
    /// the backend declared no width, so the first accepted request
    /// pins it (batches must be rectangular). Shared with the worker,
    /// which *unpins* the width again if the backend rejects a batch
    /// before any batch of that width ever succeeded — a mis-sized
    /// first guess must not lock out correctly-sized traffic forever,
    /// while a once-confirmed width survives transient backend faults.
    expected_width: Arc<AtomicUsize>,
}

impl Server {
    /// Start the worker thread over any backend. Validates the batch
    /// policy, clamps it to the backend's `max_batch`, runs the
    /// backend's [`warm`](ExecutionBackend::warm) hook, and warms the
    /// process-wide kernel worker pool (a no-op for serial budgets and
    /// on every call after the first), so batch dispatch never spawns.
    pub fn start(
        mut backend: Box<dyn ExecutionBackend>,
        config: ServerConfig,
    ) -> Result<Self, ServeError> {
        config.policy.validate()?;
        let mut policy = config.policy;
        if let Some(cap) = backend.max_batch() {
            if cap == 0 {
                return Err(ServeError::InvalidConfig(format!(
                    "backend '{}' reports max_batch == 0",
                    backend.tag()
                )));
            }
            // Shape-specialized backends cap the dynamic batch.
            policy.max_batch = policy.max_batch.min(cap);
        }
        let declared_width = backend.input_width();
        let expected_width = Arc::new(AtomicUsize::new(declared_width.unwrap_or(0)));
        // Only a *pinned* (guessed-from-traffic) width may be reset by
        // the worker on backend failure; a declared width is authoritative.
        let unpin_on_failure = if declared_width.is_none() {
            Some(Arc::clone(&expected_width))
        } else {
            None
        };
        let expected_worker = Arc::clone(&expected_width);
        let declared_classes = backend.num_classes();
        backend.warm();
        config.parallelism.warm_pool();
        let tag = backend.tag().to_string();
        let (tx, rx) = channel::<InferenceRequest>();
        let metrics = Arc::new(Metrics::new());
        let metrics_worker = Arc::clone(&metrics);
        let parallelism = config.parallelism;
        let handle = std::thread::spawn(move || {
            // Once any batch of the pinned width has succeeded, the pin
            // is confirmed and never reset: a later transient backend
            // fault must not let a stray mis-sized request steal it.
            let mut width_confirmed = false;
            while let Some(batch) = policy.next_batch(&rx) {
                let closed_at = Instant::now();
                // `submit` rejects width mismatches, so batches are
                // normally rectangular — but when an undeclared width is
                // unpinned after a failure and re-pinned by newer traffic,
                // leftover queued requests of the old width can share a
                // batch with the new one. Partition against the *current*
                // pin (falling back to the batch head when unpinned)
                // instead of trusting the invariant: stale-width requests
                // get a typed error, never a `copy_from_slice` panic.
                let width = match expected_worker.load(Ordering::Relaxed) {
                    0 => batch[0].features.len(),
                    w => w,
                };
                // Fast path: submit-side validation makes mismatches a
                // rare post-unpin edge, so don't pay partition's moves
                // and allocations on every batch.
                let batch = if batch.iter().all(|req| req.features.len() == width) {
                    batch
                } else {
                    let (keep, mismatched): (Vec<_>, Vec<_>) = batch
                        .into_iter()
                        .partition(|req| req.features.len() == width);
                    for req in mismatched {
                        metrics_worker.record_failures(1);
                        let _ = req.resp_tx.send(Err(ServeError::WidthMismatch {
                            expected: width,
                            got: req.features.len(),
                        }));
                    }
                    keep
                };
                if batch.is_empty() {
                    continue;
                }
                let rows = batch.len();
                let mut features = Matrix::zeros(rows, width);
                for (r, req) in batch.iter().enumerate() {
                    features.row_mut(r).copy_from_slice(&req.features);
                }
                let t0 = Instant::now();
                // Shape-check the backend's answer: a misbehaving
                // third-party engine must become a typed error for this
                // batch, not an out-of-bounds panic that kills the
                // worker.
                let result = backend.run_batch_with(&features, parallelism).and_then(|out| {
                    ensure!(
                        out.logits.rows == rows && out.logits.cols > 0,
                        "backend returned {}x{} logits for a {rows}-row batch",
                        out.logits.rows,
                        out.logits.cols
                    );
                    if let Some(classes) = declared_classes {
                        ensure!(
                            out.logits.cols == classes,
                            "backend returned {} logit columns, declared {classes}",
                            out.logits.cols
                        );
                    }
                    Ok(out)
                });
                let out = match result {
                    Ok(out) => out,
                    Err(e) => {
                        // Also log server-side: a client that dropped its
                        // receiver must not make the fault invisible.
                        eprintln!("[beanna::serve] backend '{tag}' error: {e:#}");
                        let err = ServeError::Backend {
                            backend: tag.clone(),
                            message: format!("{e:#}"),
                        };
                        metrics_worker.record_failures(rows);
                        // An unconfirmed pin came from this (rejected)
                        // traffic's own guess — let the next request
                        // re-pin it. A confirmed width stays.
                        if !width_confirmed {
                            if let Some(pin) = &unpin_on_failure {
                                pin.store(0, Ordering::Relaxed);
                            }
                        }
                        for req in batch {
                            let _ = req.resp_tx.send(Err(err.clone()));
                        }
                        continue;
                    }
                };
                let compute_us = t0.elapsed().as_micros() as u64;
                let queue_us: Vec<u64> = batch
                    .iter()
                    .map(|r| closed_at.duration_since(r.enqueued_at).as_micros() as u64)
                    .collect();
                metrics_worker.record_batch(rows, &queue_us, compute_us, out.sim_cycles);
                // Multi-array backends report per-shard backlogs; keep
                // the latest gauge in the metrics.
                if let Some(depths) = backend.shard_depths() {
                    metrics_worker.record_shard_depths(depths);
                }
                // Re-assert the width that actually succeeded: the pin
                // may have been cleared by an earlier failure and this
                // batch served via the head-width fallback, and a
                // confirmed width must really be the stored one.
                expected_worker.store(width, Ordering::Relaxed);
                width_confirmed = true;
                for (r, req) in batch.into_iter().enumerate() {
                    let logits = out.logits.row(r).to_vec();
                    let _ = req.resp_tx.send(Ok(InferenceResponse {
                        id: req.id,
                        prediction: argmax(&logits),
                        logits,
                        queue_us: queue_us[r],
                        compute_us,
                        batch_size: rows,
                        sim_cycles: out.sim_cycles,
                    }));
                }
            }
        });
        Ok(Self {
            tx: Some(tx),
            handle: Some(handle),
            metrics,
            next_id: AtomicU64::new(0),
            expected_width,
        })
    }

    /// Validate a request's feature width against the served model,
    /// pinning the width from the first request when the backend
    /// declared none.
    fn check_width(&self, got: usize) -> Result<(), ServeError> {
        if got == 0 {
            return Err(ServeError::EmptyRequest);
        }
        let expected = match self.expected_width.load(Ordering::Relaxed) {
            0 => match self
                .expected_width
                .compare_exchange(0, got, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => got,
                Err(winner) => winner,
            },
            w => w,
        };
        if got != expected {
            return Err(ServeError::WidthMismatch { expected, got });
        }
        Ok(())
    }

    /// Input width this server accepts, if already known.
    pub fn input_width(&self) -> Option<usize> {
        match self.expected_width.load(Ordering::Relaxed) {
            0 => None,
            w => Some(w),
        }
    }

    /// Submit asynchronously; the response (or typed error) arrives on
    /// the returned receiver. Requests whose width doesn't match the
    /// served model are rejected here — before they can reach the
    /// worker thread.
    pub fn submit(&self, features: Vec<f32>) -> Result<Receiver<ServeResult>, ServeError> {
        self.check_width(features.len())?;
        let (resp_tx, resp_rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .ok_or(ServeError::Stopped)?
            .send(InferenceRequest {
                id,
                features,
                resp_tx,
                enqueued_at: Instant::now(),
            })
            .map_err(|_| ServeError::Stopped)?;
        Ok(resp_rx)
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, features: Vec<f32>) -> Result<InferenceResponse, ServeError> {
        let rx = self.submit(features)?;
        rx.recv().map_err(|_| ServeError::ChannelClosed)?
    }

    /// Live metrics handle.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Shared handle to the live metrics registry (used by the router's
    /// load-aware policies without snapshot locking).
    pub fn metrics_handle(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Stop the server, returning the final metrics.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.tx.take(); // close the queue; worker drains and exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        self.metrics.snapshot()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::ReferenceBackend;
    use crate::nn::{Network, NetworkConfig, Precision};
    use std::time::Duration;

    fn tiny_backend() -> Box<dyn ExecutionBackend> {
        ReferenceBackend::boxed(Network::random(
            &NetworkConfig {
                sizes: vec![784, 16, 10],
                precisions: vec![Precision::Bf16, Precision::Bf16],
            },
            1,
        ))
    }

    #[test]
    fn serves_single_requests() {
        let server = Server::start(tiny_backend(), ServerConfig::default()).unwrap();
        let resp = server.infer(vec![0.5; 784]).unwrap();
        assert_eq!(resp.logits.len(), 10);
        assert!(resp.prediction < 10);
        let m = server.shutdown();
        assert_eq!(m.requests, 1);
        assert_eq!(m.batches, 1);
        assert_eq!(m.failures, 0);
    }

    #[test]
    fn batches_concurrent_requests() {
        let server = Server::start(
            tiny_backend(),
            ServerConfig {
                policy: BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_millis(30),
                },
                ..Default::default()
            },
        )
        .unwrap();
        let rxs: Vec<_> = (0..8)
            .map(|i| server.submit(vec![i as f32 / 8.0; 784]).unwrap())
            .collect();
        let resps: Vec<_> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap())
            .collect();
        assert!(resps.iter().all(|r| r.logits.len() == 10));
        // At least some requests must have shared a batch.
        let max_batch_seen = resps.iter().map(|r| r.batch_size).max().unwrap();
        assert!(max_batch_seen >= 2, "no batching happened");
        let m = server.shutdown();
        assert_eq!(m.requests, 8);
        assert!(m.batches < 8);
    }

    #[test]
    fn deterministic_predictions_match_reference() {
        let net = Network::random(
            &NetworkConfig {
                sizes: vec![784, 16, 10],
                precisions: vec![Precision::Bf16, Precision::Bf16],
            },
            1,
        );
        let image = vec![0.25; 784];
        let direct = net
            .predict(&Matrix::from_vec(1, 784, image.clone()).unwrap())
            .unwrap()[0];
        let server =
            Server::start(ReferenceBackend::boxed(net), ServerConfig::default()).unwrap();
        let resp = server.infer(image).unwrap();
        assert_eq!(resp.prediction, direct);
        server.shutdown();
    }

    #[test]
    fn shutdown_drains() {
        let server = Server::start(tiny_backend(), ServerConfig::default()).unwrap();
        let rx = server.submit(vec![0.0; 784]).unwrap();
        let m = server.shutdown();
        // The queued request is served before the worker exits.
        assert_eq!(m.requests, 1);
        assert!(rx.recv().unwrap().is_ok());
    }

    #[test]
    fn width_mismatch_rejected_at_submit() {
        let server = Server::start(tiny_backend(), ServerConfig::default()).unwrap();
        assert_eq!(server.input_width(), Some(784));
        let err = server.submit(vec![0.1; 10]).unwrap_err();
        assert_eq!(
            err,
            ServeError::WidthMismatch {
                expected: 784,
                got: 10
            }
        );
        assert_eq!(server.submit(vec![]).unwrap_err(), ServeError::EmptyRequest);
        // Well-formed traffic still flows afterwards.
        assert_eq!(server.infer(vec![0.2; 784]).unwrap().logits.len(), 10);
        server.shutdown();
    }

    #[test]
    fn zero_max_batch_is_a_config_error() {
        let err = Server::start(
            tiny_backend(),
            ServerConfig {
                policy: BatchPolicy {
                    max_batch: 0,
                    max_wait: Duration::ZERO,
                },
                ..Default::default()
            },
        )
        .err()
        .expect("max_batch 0 must be rejected");
        assert!(matches!(err, ServeError::InvalidConfig(_)));
    }

    #[test]
    fn pinned_width_unpins_after_backend_rejects_it() {
        // Declares no width, but only actually accepts 64-wide rows.
        struct Picky;
        impl ExecutionBackend for Picky {
            fn run_batch_with(
                &mut self,
                batch: &Matrix,
                _par: Parallelism,
            ) -> anyhow::Result<super::super::backend::BatchOutput> {
                anyhow::ensure!(batch.cols == 64, "device wants 64-wide rows");
                Ok(super::super::backend::BatchOutput {
                    logits: Matrix::zeros(batch.rows, 2),
                    sim_cycles: None,
                })
            }
            fn tag(&self) -> &str {
                "picky"
            }
        }
        let server = Server::start(
            Box::new(Picky),
            ServerConfig {
                policy: BatchPolicy::unbatched(),
                ..Default::default()
            },
        )
        .unwrap();
        // A wrong first guess pins 100 and fails on the backend…
        let err = server.infer(vec![0.0; 100]).unwrap_err();
        assert!(matches!(err, ServeError::Backend { .. }), "{err}");
        // …but must not lock out correctly-sized traffic afterwards.
        let ok = server.infer(vec![0.0; 64]).unwrap();
        assert_eq!(ok.logits.len(), 2);
        assert_eq!(server.input_width(), Some(64));
        server.shutdown();
    }

    #[test]
    fn width_served_after_unpin_is_stored_and_cannot_be_stolen() {
        // Accepts any width but faults on its first batch; declares none.
        struct FlakyEcho {
            failed: bool,
        }
        impl ExecutionBackend for FlakyEcho {
            fn run_batch_with(
                &mut self,
                batch: &Matrix,
                _par: Parallelism,
            ) -> anyhow::Result<super::super::backend::BatchOutput> {
                if !self.failed {
                    self.failed = true;
                    anyhow::bail!("transient hiccup");
                }
                Ok(super::super::backend::BatchOutput {
                    logits: Matrix::zeros(batch.rows, 1),
                    sim_cycles: None,
                })
            }
            fn tag(&self) -> &str {
                "flaky-echo"
            }
        }
        let server = Server::start(
            Box::new(FlakyEcho { failed: false }),
            ServerConfig {
                policy: BatchPolicy::unbatched(),
                ..Default::default()
            },
        )
        .unwrap();
        let rx_a = server.submit(vec![0.0; 100]).unwrap(); // pins 100
        let rx_b = server.submit(vec![0.0; 100]).unwrap();
        assert!(rx_a.recv().unwrap().is_err()); // fault → width unpinned
        assert!(rx_b.recv().unwrap().is_ok()); // served via head fallback
        // The width that actually served is stored back and confirmed —
        // a stray mis-sized request cannot steal the pin any more.
        assert_eq!(server.input_width(), Some(100));
        assert_eq!(
            server.submit(vec![0.0; 77]).unwrap_err(),
            ServeError::WidthMismatch {
                expected: 100,
                got: 77
            }
        );
        server.shutdown();
    }

    #[test]
    fn width_pinned_from_first_request_when_backend_is_silent() {
        // A width-agnostic backend: echoes row-sums, any width.
        struct Echo;
        impl ExecutionBackend for Echo {
            fn run_batch_with(
                &mut self,
                batch: &Matrix,
                _par: Parallelism,
            ) -> anyhow::Result<super::super::backend::BatchOutput> {
                let mut logits = Matrix::zeros(batch.rows, 1);
                for r in 0..batch.rows {
                    logits.row_mut(r)[0] = batch.row(r).iter().sum();
                }
                Ok(super::super::backend::BatchOutput {
                    logits,
                    sim_cycles: None,
                })
            }
            fn tag(&self) -> &str {
                "echo"
            }
        }
        let server = Server::start(Box::new(Echo), ServerConfig::default()).unwrap();
        assert_eq!(server.input_width(), None);
        assert_eq!(server.infer(vec![1.0; 3]).unwrap().logits, vec![3.0]);
        assert_eq!(server.input_width(), Some(3));
        // Pinned: a different width is now a typed error.
        assert_eq!(
            server.submit(vec![0.0; 4]).unwrap_err(),
            ServeError::WidthMismatch {
                expected: 3,
                got: 4
            }
        );
        server.shutdown();
    }
}
