//! The serving loop: a worker thread owning the backend, fed through the
//! dynamic batcher.

use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::backend::Backend;
use super::batcher::BatchPolicy;
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{InferenceRequest, InferenceResponse};
use crate::bf16::Matrix;
use crate::nn::metrics::argmax;
use crate::util::par::Parallelism;

/// Server configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Batching policy.
    pub policy: BatchPolicy,
    /// Kernel-parallelism budget handed to the backend for every batch
    /// (auto-sized to the host by default). A dynamic batch closed by
    /// the batcher fans its matmuls out across this many cores; logits
    /// are bit-identical at any worker count. The budget dispatches to
    /// the process-wide persistent worker pool, which [`Server::start`]
    /// constructs eagerly — so no request, not even the first, pays
    /// thread-spawn cost.
    pub parallelism: Parallelism,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            policy: BatchPolicy::default(),
            parallelism: Parallelism::default(),
        }
    }
}

/// A running inference server.
pub struct Server {
    tx: Option<Sender<InferenceRequest>>,
    handle: Option<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Server {
    /// Start the worker thread with a backend. Also warms the
    /// process-wide kernel worker pool (a no-op for serial budgets and
    /// on every call after the first), so batch dispatch never spawns.
    pub fn start(mut backend: Backend, config: ServerConfig) -> Self {
        config.parallelism.warm_pool();
        let (tx, rx) = channel::<InferenceRequest>();
        let metrics = Arc::new(Metrics::new());
        let metrics_worker = Arc::clone(&metrics);
        // PJRT backends cap the batch at their compiled shape.
        let mut policy = config.policy;
        if let Some(cap) = backend.max_batch() {
            policy.max_batch = policy.max_batch.min(cap);
        }
        let parallelism = config.parallelism;
        let handle = std::thread::spawn(move || {
            while let Some(batch) = policy.next_batch(&rx) {
                let closed_at = Instant::now();
                let rows = batch.len();
                let width = batch[0].image.len();
                let mut images = Matrix::zeros(rows, width);
                for (r, req) in batch.iter().enumerate() {
                    images.row_mut(r).copy_from_slice(&req.image);
                }
                let t0 = Instant::now();
                let out = match backend.run_batch_with(&images, parallelism) {
                    Ok(out) => out,
                    Err(e) => {
                        // Deliver an error marker: empty logits. Callers
                        // treat logits.is_empty() as failure.
                        eprintln!("backend error: {e:#}");
                        for req in batch {
                            let _ = req.resp_tx.send(InferenceResponse {
                                id: req.id,
                                logits: vec![],
                                prediction: usize::MAX,
                                queue_us: 0,
                                compute_us: 0,
                                batch_size: rows,
                                sim_cycles: None,
                            });
                        }
                        continue;
                    }
                };
                let compute_us = t0.elapsed().as_micros() as u64;
                let queue_us: Vec<u64> = batch
                    .iter()
                    .map(|r| closed_at.duration_since(r.enqueued_at).as_micros() as u64)
                    .collect();
                metrics_worker.record_batch(rows, &queue_us, compute_us, out.sim_cycles);
                for (r, req) in batch.into_iter().enumerate() {
                    let logits = out.logits.row(r).to_vec();
                    let _ = req.resp_tx.send(InferenceResponse {
                        id: req.id,
                        prediction: argmax(&logits),
                        logits,
                        queue_us: queue_us[r],
                        compute_us,
                        batch_size: rows,
                        sim_cycles: out.sim_cycles,
                    });
                }
            }
        });
        Self {
            tx: Some(tx),
            handle: Some(handle),
            metrics,
            next_id: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Submit asynchronously; the response arrives on the returned
    /// receiver.
    pub fn submit(&self, image: Vec<f32>) -> Result<std::sync::mpsc::Receiver<InferenceResponse>> {
        let (resp_tx, resp_rx) = channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("server stopped"))?
            .send(InferenceRequest {
                id,
                image,
                resp_tx,
                enqueued_at: Instant::now(),
            })
            .map_err(|_| anyhow!("server thread gone"))?;
        Ok(resp_rx)
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, image: Vec<f32>) -> Result<InferenceResponse> {
        let rx = self.submit(image)?;
        let resp = rx.recv().map_err(|_| anyhow!("response channel closed"))?;
        if resp.logits.is_empty() {
            return Err(anyhow!("backend failed for request {}", resp.id));
        }
        Ok(resp)
    }

    /// Live metrics handle.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Shared handle to the live metrics registry (used by the router's
    /// load-aware policies without snapshot locking).
    pub fn metrics_handle(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Stop the server, returning the final metrics.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.tx.take(); // close the queue; worker drains and exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        self.metrics.snapshot()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Network, NetworkConfig, Precision};
    use std::time::Duration;

    fn tiny_backend() -> Backend {
        Backend::Reference {
            net: Network::random(
                &NetworkConfig {
                    sizes: vec![784, 16, 10],
                    precisions: vec![Precision::Bf16, Precision::Bf16],
                },
                1,
            ),
        }
    }

    #[test]
    fn serves_single_requests() {
        let server = Server::start(tiny_backend(), ServerConfig::default());
        let resp = server.infer(vec![0.5; 784]).unwrap();
        assert_eq!(resp.logits.len(), 10);
        assert!(resp.prediction < 10);
        let m = server.shutdown();
        assert_eq!(m.requests, 1);
        assert_eq!(m.batches, 1);
    }

    #[test]
    fn batches_concurrent_requests() {
        let server = Server::start(
            tiny_backend(),
            ServerConfig {
                policy: BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_millis(30),
                },
                ..Default::default()
            },
        );
        let rxs: Vec<_> = (0..8)
            .map(|i| server.submit(vec![i as f32 / 8.0; 784]).unwrap())
            .collect();
        let resps: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        assert!(resps.iter().all(|r| r.logits.len() == 10));
        // At least some requests must have shared a batch.
        let max_batch_seen = resps.iter().map(|r| r.batch_size).max().unwrap();
        assert!(max_batch_seen >= 2, "no batching happened");
        let m = server.shutdown();
        assert_eq!(m.requests, 8);
        assert!(m.batches < 8);
    }

    #[test]
    fn deterministic_predictions_match_reference() {
        let net = Network::random(
            &NetworkConfig {
                sizes: vec![784, 16, 10],
                precisions: vec![Precision::Bf16, Precision::Bf16],
            },
            1,
        );
        let image = vec![0.25; 784];
        let direct = net
            .predict(&Matrix::from_vec(1, 784, image.clone()).unwrap())
            .unwrap()[0];
        let server = Server::start(Backend::Reference { net }, ServerConfig::default());
        let resp = server.infer(image).unwrap();
        assert_eq!(resp.prediction, direct);
        server.shutdown();
    }

    #[test]
    fn shutdown_drains() {
        let server = Server::start(tiny_backend(), ServerConfig::default());
        let rx = server.submit(vec![0.0; 784]).unwrap();
        let m = server.shutdown();
        // The queued request is served before the worker exits.
        assert_eq!(m.requests, 1);
        assert!(rx.recv().is_ok());
    }
}
