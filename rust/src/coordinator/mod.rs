//! Inference coordinator: the serving layer around the accelerator.
//!
//! The paper's device is commanded over AXI-Lite by "software or a
//! external hardware controller" (§III-D step 1); this module is that
//! controller, built like a miniature serving stack:
//!
//! * [`request`] — request/response types.
//! * [`batcher`] — dynamic batching: collect requests up to a maximum
//!   batch (the paper evaluates 1 and 256) or a deadline, whichever
//!   comes first.
//! * [`backend`] — the execution target: the cycle-level simulator, the
//!   PJRT runtime running the AOT artifacts, or the pure-rust reference
//!   model. All three produce logits; the simulator also reports cycles.
//! * [`server`] — a worker thread that owns the backend, drains the
//!   queue through the batcher, and records [`metrics`].
//!
//! Everything is `std::thread` + channels — no async runtime in the
//! vendored crate set, and a single-device coordinator does not need
//! one.

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use backend::Backend;
pub use batcher::BatchPolicy;
pub use metrics::MetricsSnapshot;
pub use request::{InferenceRequest, InferenceResponse};
pub use router::{RoutePolicy, Router};
pub use server::{Server, ServerConfig};

// The kernel-parallelism budget carried by [`ServerConfig`] (and its
// dispatch-strategy knob); re-exported so serving callers don't need to
// reach into `util::par`.
pub use crate::util::par::{Dispatch, Parallelism};
