//! Inference coordinator: the serving layer around the accelerator.
//!
//! The paper's device is commanded over AXI-Lite by "software or a
//! external hardware controller" (§III-D step 1); this module is that
//! controller, built like a miniature serving stack:
//!
//! * [`request`] — the request lifecycle: request/response types
//!   (arbitrary feature/class widths; shapes come from the served
//!   model's config), per-request QoS ([`SubmitOptions`]: deadline +
//!   [`Priority`]), and the owned [`Ticket`] every submission resolves
//!   through (`wait`/`wait_timeout`/`try_wait`/`cancel`; dropping an
//!   unresolved ticket cancels an undispatched request).
//! * [`error`] — typed serving failures ([`ServeError`]); every
//!   ticket resolves to a [`ServeResult`], never a sentinel.
//! * [`batcher`] — QoS-aware dynamic batching: a two-class priority
//!   queue that collects requests up to a maximum batch (the paper
//!   evaluates 1 and 256) or a wait deadline, drains Interactive
//!   before Bulk, and drops expired or cancelled requests at
//!   batch-formation time — they never reach the backend.
//! * [`backend`] — the **open** execution seam: anything implementing
//!   the object-safe [`ExecutionBackend`] trait plugs in as a
//!   `Box<dyn ExecutionBackend>`. In-tree: [`ReferenceBackend`] (pure
//!   rust), [`SimulatorBackend`] (cycle-level device model),
//!   [`ShardedSimulatorBackend`] (N modeled arrays behind one AXI
//!   front-end, per-shard queue depths in the metrics), and the
//!   PJRT runtime (implementation behind the `pjrt` feature; the
//!   [`pjrt`](backend::pjrt) constructor exists in every build).
//! * [`server`] — a worker thread that owns one backend, drains the
//!   queue through the batcher, and records [`metrics`]. The queue is
//!   a real admission point: [`ServerConfig::queue_capacity`] bounds
//!   in-flight requests and overflow is a synchronous
//!   [`ServeError::Overloaded`] at submit time.
//! * [`router`] — replicas of one model behind a worker-selection
//!   policy (round-robin, join-the-shortest-queue on host-side
//!   outstanding counts, or [`RoutePolicy::ModeledBacklog`] on the
//!   modeled backlogs sharded simulator workers report). The router is
//!   also the fault-tolerance layer: per-replica circuit breakers
//!   ([`HealthState`]: eject → probe → readmit), transparent retry of
//!   failed attempts on healthy replicas under a [`RetryPolicy`]
//!   (deadline- and budget-aware exponential backoff), and graceful
//!   drain ([`Router::begin_drain`] — typed
//!   [`ServeError::ShuttingDown`] while queued work flushes).
//! * [`fault`] — deterministic, seedable chaos:
//!   [`FaultInjectingBackend`] wraps any backend and injects typed
//!   errors, latency, garbage logits, and panics at configured rates —
//!   the harness the fault-tolerance layer is tested against.
//! * [`engine`] — the top-level facade: **multiple named models
//!   behind one submit surface**, one router-managed worker group per
//!   model, built with the fluent [`EngineBuilder`].
//!
//! Everything is `std::thread` + channels — no async runtime in the
//! vendored crate set.
//!
//! ```no_run
//! use beanna::coordinator::Engine;
//! use beanna::nn::{Network, NetworkConfig};
//!
//! let net = Network::random(&NetworkConfig::beanna_hybrid(), 7);
//! let engine = Engine::builder().model("hybrid", net).replicas(2).build()?;
//! let resp = engine.infer("hybrid", vec![0.5; 784])?;
//! assert_eq!(resp.logits.len(), 10);
//! # anyhow::Ok(())
//! ```

pub mod backend;
pub mod batcher;
pub mod engine;
pub mod error;
pub mod fault;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use backend::{
    pjrt, BatchOutput, ExecutionBackend, ReferenceBackend, ShardedSimulatorBackend,
    SimulatorBackend, TransportStats,
};
#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
pub use batcher::{BatchPolicy, BatchQueue};
pub use engine::{BackendFactory, Engine, EngineBuilder};
pub use error::{ServeError, ServeResult};
pub use fault::{FaultInjectingBackend, FaultSpec, InjectionCounts};
pub use metrics::{HealthState, MetricsSnapshot};
pub use request::{InferenceRequest, InferenceResponse, Priority, SubmitOptions, Ticket};
pub use router::{RetryPolicy, RoutePolicy, RoutedTicket, Router};
pub use server::{Server, ServerConfig, ROWS_PER_WORKER};

// The kernel-parallelism budget carried by [`ServerConfig`] (and its
// dispatch-strategy knob); re-exported so serving callers don't need to
// reach into `util::par`.
pub use crate::util::par::{Dispatch, Parallelism};
