//! Dynamic batching with QoS: a two-class priority queue in front of
//! the backend.
//!
//! A batch closes at `max_batch` live requests or when the oldest live
//! request has waited `max_wait`, whichever is first — but batch
//! *formation* is now an active admission step, not a blind drain:
//!
//! * **Priority.** Queued [`Priority::Interactive`] requests are taken
//!   before any [`Priority::Bulk`] one. Under a saturated queue,
//!   interactive traffic overtakes earlier-submitted bulk backfill.
//! * **Deadlines first (EDF).** Within a class, deadlined requests are
//!   ordered earliest-deadline-first and all of them lead undeadlined
//!   traffic, which stays FIFO behind them. The requests closest to
//!   expiring have the least slack, so serving them first converts
//!   would-be `DeadlineExceeded` drops into answers — and undeadlined
//!   requests have, by construction, declared they can wait.
//! * **Expiry.** A request whose deadline passed while it queued is
//!   dropped here with [`ServeError::DeadlineExceeded`] — it never
//!   reaches the backend, so an overloaded server spends no compute on
//!   answers nobody is waiting for.
//! * **Cancellation.** A request whose ticket was cancelled (or
//!   dropped) is discarded silently; its admission slot was already
//!   released at cancel time.

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use super::error::ServeError;
use super::metrics::Metrics;
use super::request::{InferenceRequest, Priority};

/// Batch-closing policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Maximum rows per batch (the paper evaluates 1 and 256).
    pub max_batch: usize,
    /// Maximum time the first request in a batch may wait.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 256,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// The batcher's working state: the raw channel from `submit` plus the
/// two priority classes requests are staged into between batches.
/// Owned by the server's worker thread.
pub struct BatchQueue {
    rx: Receiver<InferenceRequest>,
    interactive: VecDeque<InferenceRequest>,
    bulk: VecDeque<InferenceRequest>,
}

impl BatchQueue {
    /// Wrap the server's request channel.
    pub fn new(rx: Receiver<InferenceRequest>) -> Self {
        Self {
            rx,
            interactive: VecDeque::new(),
            bulk: VecDeque::new(),
        }
    }

    /// Stage a request into its priority class, keeping the class in
    /// EDF order: a sorted run of deadlined requests (earliest first),
    /// then undeadlined requests in arrival order. The insert is
    /// stable — equal deadlines stay FIFO — and every later removal
    /// (sweep, take) preserves relative order, so the invariant holds
    /// for the queue's whole lifetime.
    fn stage(&mut self, req: InferenceRequest) {
        let class = match req.priority {
            Priority::Interactive => &mut self.interactive,
            Priority::Bulk => &mut self.bulk,
        };
        match req.deadline {
            Some(due) => {
                let at = class
                    .iter()
                    .take_while(|r| matches!(r.deadline, Some(d) if d <= due))
                    .count();
                class.insert(at, req);
            }
            None => class.push_back(req),
        }
    }

    /// Drain everything already sitting in the channel (non-blocking).
    fn pump(&mut self) {
        while let Ok(req) = self.rx.try_recv() {
            self.stage(req);
        }
    }

    /// Requests currently staged (either class).
    fn staged(&self) -> usize {
        self.interactive.len() + self.bulk.len()
    }

    /// Sweep both classes: discard cancelled requests (counted, no
    /// response — the ticket holder walked away) and resolve expired
    /// ones with a typed [`ServeError::DeadlineExceeded`]. Runs at
    /// batch-formation time, so an expired request provably never
    /// reaches the backend. The all-live fast path allocates nothing.
    fn sweep(&mut self, now: Instant, metrics: &Metrics) {
        for class in [&mut self.interactive, &mut self.bulk] {
            if class
                .iter()
                .all(|req| !req.is_cancelled() && !req.expired_at(now))
            {
                continue;
            }
            let mut kept = VecDeque::with_capacity(class.len());
            for req in class.drain(..) {
                if req.is_cancelled() {
                    metrics.record_cancelled(1);
                } else if req.is_expired() {
                    // The ticket already expired it client-side (and
                    // resolved the waiter); just record and discard.
                    metrics.record_expired(1);
                } else if req.expired_at(now) {
                    // Claim the request before resolving it, so a
                    // ticket's later `cancel()` correctly reports
                    // "too late" instead of pretending to withdraw an
                    // already-resolved request; losing the claim means
                    // the ticket cancelled or self-expired concurrently.
                    if req.try_dispatch() {
                        metrics.record_expired(1);
                        let waited_us = req.waited_us(now);
                        req.resolve(Err(ServeError::DeadlineExceeded { waited_us }));
                    } else if req.is_expired() {
                        metrics.record_expired(1);
                    } else {
                        metrics.record_cancelled(1);
                    }
                } else {
                    kept.push_back(req);
                }
            }
            *class = kept;
        }
    }

    /// Take up to `max` requests, interactive class first, claiming
    /// each for dispatch. A request cancelled or ticket-expired
    /// between the sweep and this claim loses the race and is counted
    /// instead of taken.
    fn take(&mut self, max: usize, metrics: &Metrics) -> Vec<InferenceRequest> {
        let mut batch = Vec::new();
        for class in [&mut self.interactive, &mut self.bulk] {
            while batch.len() < max {
                match class.pop_front() {
                    Some(req) if req.try_dispatch() => batch.push(req),
                    Some(dead) if dead.is_expired() => metrics.record_expired(1),
                    Some(_cancelled) => metrics.record_cancelled(1),
                    None => break,
                }
            }
        }
        batch
    }
}

impl BatchPolicy {
    /// Single-request batches (the paper's batch-1 configuration).
    pub fn unbatched() -> Self {
        Self {
            max_batch: 1,
            max_wait: Duration::ZERO,
        }
    }

    /// Reject unusable policies before any worker starts: a
    /// `max_batch` of zero ("batches of at most zero requests") is
    /// contradictory, and the worker loop's behaviour under it was
    /// accidental. Callers get a typed config error instead.
    pub fn validate(&self) -> Result<(), super::error::ServeError> {
        if self.max_batch == 0 {
            return Err(super::error::ServeError::InvalidConfig(
                "BatchPolicy::max_batch must be at least 1".into(),
            ));
        }
        Ok(())
    }

    /// Form the next batch from `queue`. Blocks until at least one
    /// *live* (uncancelled, unexpired) request is available; returns
    /// `None` when the channel is closed and fully drained. Expired
    /// requests are resolved with `DeadlineExceeded` and cancelled
    /// ones discarded at formation time, and the returned batch is
    /// ordered interactive-before-bulk; within a class, deadlined
    /// requests lead earliest-deadline-first, undeadlined follow FIFO.
    pub fn next_batch(
        &self,
        queue: &mut BatchQueue,
        metrics: &Metrics,
    ) -> Option<Vec<InferenceRequest>> {
        loop {
            // Phase 1: wait for at least one live request.
            loop {
                queue.pump();
                queue.sweep(Instant::now(), metrics);
                if queue.staged() > 0 {
                    break;
                }
                match queue.rx.recv() {
                    Ok(req) => queue.stage(req),
                    Err(_) => return None, // closed + drained
                }
            }
            // Phase 2: hold the batch open up to `max_wait` for more.
            // No per-arrival sweep — a dead entry merely inflates the
            // staged count (closing the window early with a smaller
            // batch), and the single sweep below settles it.
            let deadline = Instant::now() + self.max_wait;
            while queue.staged() < self.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    queue.pump();
                    break;
                }
                match queue.rx.recv_timeout(deadline - now) {
                    Ok(req) => {
                        queue.stage(req);
                        queue.pump();
                    }
                    Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            // Phase 3: one sweep at formation (this is what guarantees
            // an expired request never reaches the backend), then
            // claim, interactive first. A cancel racing the claim just
            // shrinks the batch, and an all-dead window loops back to
            // waiting.
            queue.sweep(Instant::now(), metrics);
            let batch = queue.take(self.max_batch, metrics);
            if !batch.is_empty() {
                return Some(batch);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{SubmitOptions, Ticket};
    use std::sync::mpsc::{channel, Sender};
    use std::time::Instant;

    /// Test fixture: a request flowing through the real `Ticket`
    /// plumbing. The returned ticket must be *held* by the test — a
    /// dropped ticket cancels its request, which is itself behaviour
    /// under test below.
    fn send(tx: &Sender<InferenceRequest>, id: u64, opts: SubmitOptions) -> Ticket {
        let (req, ticket) = InferenceRequest::fresh(id, vec![], opts);
        tx.send(req).unwrap();
        ticket
    }

    fn ids(batch: &[InferenceRequest]) -> Vec<u64> {
        batch.iter().map(|r| r.id).collect()
    }

    #[test]
    fn zero_max_batch_fails_validation() {
        assert!(BatchPolicy {
            max_batch: 0,
            max_wait: Duration::ZERO,
        }
        .validate()
        .is_err());
        assert!(BatchPolicy::default().validate().is_ok());
        assert!(BatchPolicy::unbatched().validate().is_ok());
    }

    #[test]
    fn fills_to_max_batch_when_queue_is_deep() {
        let (tx, rx) = channel();
        let mut q = BatchQueue::new(rx);
        let m = Metrics::new();
        let _tickets: Vec<_> = (0..10).map(|i| send(&tx, i, SubmitOptions::default())).collect();
        let p = BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(50),
        };
        let b1 = p.next_batch(&mut q, &m).unwrap();
        assert_eq!(ids(&b1), vec![0, 1, 2, 3]);
        let b2 = p.next_batch(&mut q, &m).unwrap();
        assert_eq!(b2.len(), 4);
    }

    #[test]
    fn deadline_closes_partial_batch() {
        let (tx, rx) = channel();
        let mut q = BatchQueue::new(rx);
        let m = Metrics::new();
        let _t = send(&tx, 0, SubmitOptions::default());
        let p = BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(10),
        };
        let t0 = Instant::now();
        let b = p.next_batch(&mut q, &m).unwrap();
        assert_eq!(b.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn unbatched_returns_singletons_immediately() {
        let (tx, rx) = channel();
        let mut q = BatchQueue::new(rx);
        let m = Metrics::new();
        let _t1 = send(&tx, 1, SubmitOptions::default());
        let _t2 = send(&tx, 2, SubmitOptions::default());
        let p = BatchPolicy::unbatched();
        assert_eq!(p.next_batch(&mut q, &m).unwrap().len(), 1);
        assert_eq!(p.next_batch(&mut q, &m).unwrap().len(), 1);
    }

    #[test]
    fn closed_channel_yields_none_after_drain() {
        let (tx, rx) = channel();
        let mut q = BatchQueue::new(rx);
        let m = Metrics::new();
        let _t = send(&tx, 5, SubmitOptions::default());
        drop(tx);
        let p = BatchPolicy::default();
        assert_eq!(p.next_batch(&mut q, &m).unwrap().len(), 1);
        assert!(p.next_batch(&mut q, &m).is_none());
    }

    #[test]
    fn interactive_taken_before_earlier_bulk() {
        let (tx, rx) = channel();
        let mut q = BatchQueue::new(rx);
        let m = Metrics::new();
        // Bulk submitted first, interactive after — interactive still
        // leads the batch, and each class stays FIFO.
        let _tickets = [
            send(&tx, 0, SubmitOptions::bulk()),
            send(&tx, 1, SubmitOptions::bulk()),
            send(&tx, 2, SubmitOptions::default()),
            send(&tx, 3, SubmitOptions::default()),
        ];
        let p = BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_millis(20),
        };
        let b = p.next_batch(&mut q, &m).unwrap();
        assert_eq!(ids(&b), vec![2, 3, 0], "interactive first, then bulk FIFO");
        let b = p.next_batch(&mut q, &m).unwrap();
        assert_eq!(ids(&b), vec![1]);
    }

    #[test]
    fn deadlined_requests_lead_their_class_in_edf_order() {
        let (tx, rx) = channel();
        let mut q = BatchQueue::new(rx);
        let m = Metrics::new();
        // Arrival order deliberately scrambles urgency: undeadlined
        // first, then a loose deadline, then the tightest, then a
        // middle one. All deadlines are far enough out never to expire
        // during the test.
        let _tickets = [
            send(&tx, 0, SubmitOptions::default()),
            send(&tx, 1, SubmitOptions::default().with_deadline(Duration::from_secs(30))),
            send(&tx, 2, SubmitOptions::default().with_deadline(Duration::from_secs(10))),
            send(&tx, 3, SubmitOptions::default().with_deadline(Duration::from_secs(20))),
            send(&tx, 4, SubmitOptions::default()),
        ];
        let p = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(20),
        };
        let b = p.next_batch(&mut q, &m).unwrap();
        assert_eq!(
            ids(&b),
            vec![2, 3, 1, 0, 4],
            "EDF among deadlined, then undeadlined FIFO"
        );
    }

    #[test]
    fn edf_is_scoped_to_a_class_and_stable_within_it() {
        let (tx, rx) = channel();
        let mut q = BatchQueue::new(rx);
        let m = Metrics::new();
        // A tight-deadline *bulk* request must not overtake interactive
        // traffic: priority still dominates, EDF only reorders peers.
        // And two interactive requests sharing a deadline stay FIFO.
        let shared = Duration::from_secs(15);
        let _tickets = [
            send(&tx, 0, SubmitOptions::bulk().with_deadline(Duration::from_secs(1))),
            send(&tx, 1, SubmitOptions::default().with_deadline(shared)),
            send(&tx, 2, SubmitOptions::default().with_deadline(shared)),
            send(&tx, 3, SubmitOptions::default()),
        ];
        let p = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(20),
        };
        let b = p.next_batch(&mut q, &m).unwrap();
        assert_eq!(
            ids(&b),
            vec![1, 2, 3, 0],
            "interactive class intact (equal deadlines FIFO), bulk last"
        );
    }

    #[test]
    fn expired_requests_resolve_without_reaching_a_batch() {
        let (tx, rx) = channel();
        let mut q = BatchQueue::new(rx);
        let m = Metrics::new();
        let dead = send(
            &tx,
            0,
            SubmitOptions::default().with_deadline(Duration::ZERO),
        );
        let live = send(&tx, 1, SubmitOptions::default());
        let p = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        };
        let b = p.next_batch(&mut q, &m).unwrap();
        assert_eq!(ids(&b), vec![1], "expired request must not be batched");
        match dead.wait().unwrap_err() {
            ServeError::DeadlineExceeded { .. } => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        assert_eq!(m.snapshot().expired, 1);
        drop(live);
    }

    #[test]
    fn cancelled_requests_are_swept_not_batched() {
        let (tx, rx) = channel();
        let mut q = BatchQueue::new(rx);
        let m = Metrics::new();
        let t0 = send(&tx, 0, SubmitOptions::default());
        let _t1 = send(&tx, 1, SubmitOptions::default());
        assert!(t0.cancel());
        let p = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        };
        let b = p.next_batch(&mut q, &m).unwrap();
        assert_eq!(ids(&b), vec![1]);
        assert_eq!(m.snapshot().cancelled, 1);
        // A ticket *dropped* (not explicitly cancelled) behaves the
        // same: the request never surfaces in a batch.
        let t2 = send(&tx, 2, SubmitOptions::default());
        drop(t2);
        let _t3 = send(&tx, 3, SubmitOptions::default());
        let b = p.next_batch(&mut q, &m).unwrap();
        assert_eq!(ids(&b), vec![3]);
        assert_eq!(m.snapshot().cancelled, 2);
    }
}
