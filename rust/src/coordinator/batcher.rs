//! Dynamic batching policy: close a batch at `max_batch` requests or
//! when the oldest queued request has waited `max_wait`, whichever is
//! first.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use super::request::InferenceRequest;

/// Batch-closing policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Maximum rows per batch (the paper evaluates 1 and 256).
    pub max_batch: usize,
    /// Maximum time the first request in a batch may wait.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 256,
            max_wait: Duration::from_millis(2),
        }
    }
}

impl BatchPolicy {
    /// Single-request batches (the paper's batch-1 configuration).
    pub fn unbatched() -> Self {
        Self {
            max_batch: 1,
            max_wait: Duration::ZERO,
        }
    }

    /// Reject unusable policies before any worker starts: a
    /// `max_batch` of zero ("batches of at most zero requests") is
    /// contradictory, and the worker loop's behaviour under it was
    /// accidental. Callers get a typed config error instead.
    pub fn validate(&self) -> Result<(), super::error::ServeError> {
        if self.max_batch == 0 {
            return Err(super::error::ServeError::InvalidConfig(
                "BatchPolicy::max_batch must be at least 1".into(),
            ));
        }
        Ok(())
    }

    /// Pull the next batch from `rx`. Blocks for the first request;
    /// returns `None` when the channel is closed and drained.
    pub fn next_batch(&self, rx: &Receiver<InferenceRequest>) -> Option<Vec<InferenceRequest>> {
        let first = rx.recv().ok()?;
        let deadline = Instant::now() + self.max_wait;
        let mut batch = vec![first];
        while batch.len() < self.max_batch {
            let now = Instant::now();
            if now >= deadline {
                // Deadline passed: take anything already queued, without
                // blocking, then close.
                match rx.try_recv() {
                    Ok(req) => batch.push(req),
                    Err(_) => break,
                }
                continue;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(req) => batch.push(req),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    fn req(id: u64) -> InferenceRequest {
        let (tx, _rx) = channel();
        // Keep _rx alive by leaking: tests only inspect ids.
        std::mem::forget(_rx);
        InferenceRequest {
            id,
            features: vec![],
            resp_tx: tx,
            enqueued_at: Instant::now(),
        }
    }

    #[test]
    fn zero_max_batch_fails_validation() {
        assert!(BatchPolicy {
            max_batch: 0,
            max_wait: Duration::ZERO,
        }
        .validate()
        .is_err());
        assert!(BatchPolicy::default().validate().is_ok());
        assert!(BatchPolicy::unbatched().validate().is_ok());
    }

    #[test]
    fn fills_to_max_batch_when_queue_is_deep() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(req(i)).unwrap();
        }
        let p = BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(50),
        };
        let b1 = p.next_batch(&rx).unwrap();
        assert_eq!(b1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        let b2 = p.next_batch(&rx).unwrap();
        assert_eq!(b2.len(), 4);
    }

    #[test]
    fn deadline_closes_partial_batch() {
        let (tx, rx) = channel();
        tx.send(req(0)).unwrap();
        let p = BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(10),
        };
        let t0 = Instant::now();
        let b = p.next_batch(&rx).unwrap();
        assert_eq!(b.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn unbatched_returns_singletons_immediately() {
        let (tx, rx) = channel();
        tx.send(req(1)).unwrap();
        tx.send(req(2)).unwrap();
        let p = BatchPolicy::unbatched();
        assert_eq!(p.next_batch(&rx).unwrap().len(), 1);
        assert_eq!(p.next_batch(&rx).unwrap().len(), 1);
    }

    #[test]
    fn closed_channel_yields_none_after_drain() {
        let (tx, rx) = channel();
        tx.send(req(5)).unwrap();
        drop(tx);
        let p = BatchPolicy::default();
        assert_eq!(p.next_batch(&rx).unwrap().len(), 1);
        assert!(p.next_batch(&rx).is_none());
    }
}
