//! Multi-device request router: fan a request stream across several
//! accelerator workers (the natural scale-out of the paper's device —
//! one BEANNA per FPGA/SLR, one serving queue per device). Workers are
//! replicas of the same model; any mix of [`ExecutionBackend`]
//! implementations works behind one router.
//!
//! Policies:
//! * [`RoutePolicy::RoundRobin`] — stateless rotation.
//! * [`RoutePolicy::LeastOutstanding`] — join-the-shortest-queue on
//!   (submitted − answered), the standard router heuristic for
//!   heterogeneous workers (cf. vLLM's router).
//! * [`RoutePolicy::ModeledBacklog`] — join-the-shortest-queue on the
//!   **modeled** per-shard backlogs sharded simulator workers report
//!   through [`ExecutionBackend::shard_depths`]. Host-side outstanding
//!   counts go blind behind a device model: responses return at host
//!   speed while the modeled device still owes cycles, so
//!   `LeastOutstanding` reads every worker as idle. The modeled gauge
//!   keeps the skew visible. Workers that report no depths score 0 and
//!   fall back to the outstanding tie-break, so the policy degrades
//!   gracefully to `LeastOutstanding` for single-device backends.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::backend::ExecutionBackend;
use super::error::ServeError;
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{InferenceResponse, SubmitOptions, Ticket};
use super::server::{Server, ServerConfig};

/// Worker-selection policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Rotate through workers.
    RoundRobin,
    /// Pick the worker with the fewest outstanding requests.
    LeastOutstanding,
    /// Pick the worker whose backend reports the smallest summed
    /// modeled backlog (`shard_depths`), breaking ties on host-side
    /// outstanding counts.
    ModeledBacklog,
}

struct Worker {
    server: Server,
    submitted: AtomicU64,
    metrics: Arc<Metrics>,
}

impl Worker {
    fn outstanding(&self) -> u64 {
        self.submitted
            .load(Ordering::Relaxed)
            .saturating_sub(self.metrics.requests_fast())
    }
}

/// The router: owns one [`Server`] per backend.
pub struct Router {
    workers: Vec<Worker>,
    policy: RoutePolicy,
    next: AtomicU64,
}

impl Router {
    /// Start one server per backend, all with the same serving config.
    pub fn start(
        backends: Vec<Box<dyn ExecutionBackend>>,
        config: ServerConfig,
        policy: RoutePolicy,
    ) -> Result<Self, ServeError> {
        if backends.is_empty() {
            return Err(ServeError::InvalidConfig(
                "router needs at least one backend".into(),
            ));
        }
        let workers = backends
            .into_iter()
            .map(|b| {
                let server = Server::start(b, config)?;
                let metrics = server.metrics_handle();
                Ok(Worker {
                    server,
                    submitted: AtomicU64::new(0),
                    metrics,
                })
            })
            .collect::<Result<Vec<_>, ServeError>>()?;
        Ok(Self {
            workers,
            policy,
            next: AtomicU64::new(0),
        })
    }

    /// Number of workers.
    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// Pick a worker index under the configured policy.
    fn pick(&self) -> usize {
        match self.policy {
            RoutePolicy::RoundRobin => {
                (self.next.fetch_add(1, Ordering::Relaxed) as usize) % self.workers.len()
            }
            RoutePolicy::LeastOutstanding => self
                .workers
                .iter()
                .enumerate()
                .min_by_key(|(_, w)| w.outstanding())
                .map(|(i, _)| i)
                .unwrap(),
            RoutePolicy::ModeledBacklog => self
                .workers
                .iter()
                .enumerate()
                .min_by_key(|(_, w)| (w.metrics.shard_backlog_fast(), w.outstanding()))
                .map(|(i, _)| i)
                .unwrap(),
        }
    }

    /// Submit with explicit QoS options; returns (worker index,
    /// ticket). Admission rejections ([`ServeError::Overloaded`]) come
    /// from the chosen worker's bounded queue — the router does not
    /// retry another worker, so backpressure stays visible to the
    /// caller.
    pub fn submit_with(
        &self,
        features: Vec<f32>,
        opts: SubmitOptions,
    ) -> Result<(usize, Ticket), ServeError> {
        let i = self.pick();
        let ticket = self.workers[i].server.submit_with(features, opts)?;
        self.workers[i].submitted.fetch_add(1, Ordering::Relaxed);
        Ok((i, ticket))
    }

    /// Submit with default options; returns (worker index, ticket).
    pub fn submit(&self, features: Vec<f32>) -> Result<(usize, Ticket), ServeError> {
        self.submit_with(features, SubmitOptions::default())
    }

    /// Submit and wait.
    pub fn infer(&self, features: Vec<f32>) -> Result<InferenceResponse, ServeError> {
        let (_, ticket) = self.submit(features)?;
        ticket.wait()
    }

    /// Per-worker outstanding counts (diagnostics).
    pub fn outstanding(&self) -> Vec<u64> {
        self.workers.iter().map(|w| w.outstanding()).collect()
    }

    /// Per-worker live metrics snapshots.
    pub fn metrics(&self) -> Vec<MetricsSnapshot> {
        self.workers.iter().map(|w| w.server.metrics()).collect()
    }

    /// Stop all workers, returning their final metrics.
    pub fn shutdown(self) -> Vec<MetricsSnapshot> {
        self.workers
            .into_iter()
            .map(|w| w.server.shutdown())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{ReferenceBackend, SimulatorBackend};
    use crate::coordinator::BatchPolicy;
    use crate::nn::{Network, NetworkConfig, Precision};
    use std::time::Duration;

    fn net(seed: u64) -> Network {
        Network::random(
            &NetworkConfig {
                sizes: vec![784, 16, 10],
                precisions: vec![Precision::Bf16, Precision::Bf16],
            },
            seed,
        )
    }

    fn config() -> ServerConfig {
        ServerConfig {
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            ..Default::default()
        }
    }

    #[test]
    fn round_robin_spreads_evenly() {
        let router = Router::start(
            vec![
                ReferenceBackend::boxed(net(1)),
                ReferenceBackend::boxed(net(1)),
                ReferenceBackend::boxed(net(1)),
            ],
            config(),
            RoutePolicy::RoundRobin,
        )
        .unwrap();
        let mut counts = [0usize; 3];
        let tickets: Vec<_> = (0..30)
            .map(|_| {
                let (i, t) = router.submit(vec![0.1; 784]).unwrap();
                counts[i] += 1;
                t
            })
            .collect();
        for t in tickets {
            assert!(!t.wait().unwrap().logits.is_empty());
        }
        assert_eq!(counts, [10, 10, 10]);
        let metrics = router.shutdown();
        assert_eq!(metrics.iter().map(|m| m.requests).sum::<u64>(), 30);
    }

    #[test]
    fn least_outstanding_avoids_loaded_worker() {
        let router = Router::start(
            vec![ReferenceBackend::boxed(net(1)), ReferenceBackend::boxed(net(2))],
            config(),
            RoutePolicy::LeastOutstanding,
        )
        .unwrap();
        // Submit a burst without receiving; JSQ must not send everything
        // to one worker.
        let tickets: Vec<_> = (0..40)
            .map(|_| router.submit(vec![0.2; 784]).unwrap())
            .collect();
        let mut counts = [0usize; 2];
        for (i, _) in &tickets {
            counts[*i] += 1;
        }
        assert!(counts[0] >= 10 && counts[1] >= 10, "{counts:?}");
        for (_, t) in tickets {
            t.wait().unwrap();
        }
        router.shutdown();
    }

    #[test]
    fn all_workers_produce_identical_results_for_same_weights() {
        let router = Router::start(
            vec![ReferenceBackend::boxed(net(7)), SimulatorBackend::boxed(net(7))],
            config(),
            RoutePolicy::RoundRobin,
        )
        .unwrap();
        // Same image through both workers (round-robin alternates).
        let a = router.infer(vec![0.3; 784]).unwrap();
        let b = router.infer(vec![0.3; 784]).unwrap();
        assert_eq!(a.prediction, b.prediction);
        router.shutdown();
    }

    #[test]
    fn modeled_backlog_without_depths_degrades_to_outstanding() {
        // Reference backends report no shard depths, so every worker
        // scores 0 and the outstanding tie-break decides: a burst must
        // still spread instead of piling on worker 0.
        let router = Router::start(
            vec![ReferenceBackend::boxed(net(3)), ReferenceBackend::boxed(net(4))],
            config(),
            RoutePolicy::ModeledBacklog,
        )
        .unwrap();
        let tickets: Vec<_> = (0..40)
            .map(|_| router.submit(vec![0.2; 784]).unwrap())
            .collect();
        let mut counts = [0usize; 2];
        for (i, _) in &tickets {
            counts[*i] += 1;
        }
        assert!(counts[0] >= 10 && counts[1] >= 10, "{counts:?}");
        for (_, t) in tickets {
            t.wait().unwrap();
        }
        router.shutdown();
    }

    #[test]
    fn empty_router_rejected() {
        let err = Router::start(vec![], config(), RoutePolicy::RoundRobin)
            .err()
            .expect("empty router must be rejected");
        assert!(matches!(err, ServeError::InvalidConfig(_)));
    }
}
