//! Multi-device request router: fan a request stream across several
//! accelerator workers (the natural scale-out of the paper's device —
//! one BEANNA per FPGA/SLR, one serving queue per device). Workers are
//! replicas of the same model; any mix of [`ExecutionBackend`]
//! implementations works behind one router.
//!
//! Policies:
//! * [`RoutePolicy::RoundRobin`] — stateless rotation.
//! * [`RoutePolicy::LeastOutstanding`] — join-the-shortest-queue on
//!   (submitted − answered), the standard router heuristic for
//!   heterogeneous workers (cf. vLLM's router).
//! * [`RoutePolicy::ModeledBacklog`] — join-the-shortest-queue on the
//!   **modeled** per-shard backlogs sharded simulator workers report
//!   through [`ExecutionBackend::shard_depths`]. Host-side outstanding
//!   counts go blind behind a device model: responses return at host
//!   speed while the modeled device still owes cycles, so
//!   `LeastOutstanding` reads every worker as idle. The modeled gauge
//!   keeps the skew visible. Workers that report no depths score 0 and
//!   fall back to the outstanding tie-break, so the policy degrades
//!   gracefully to `LeastOutstanding` for single-device backends.
//!
//! # Fault tolerance
//!
//! The router is also the serving stack's reliability layer:
//!
//! * **Per-replica health.** Every worker carries a consecutive-failure
//!   circuit breaker ([`HealthState`]): [`RetryPolicy::breaker_threshold`]
//!   consecutive backend failures eject it from the routing rotation
//!   (Closed → Open). After [`RetryPolicy::probe_cooldown`] the next
//!   pick routes exactly **one** probe request to it (Open → HalfOpen);
//!   a successful probe readmits it (→ Closed), a failed one re-ejects.
//!   Ejections, readmissions, and the live state surface per replica in
//!   [`MetricsSnapshot`].
//! * **Retry with backoff.** [`submit_with`](Router::submit_with)
//!   returns a [`RoutedTicket`]: when an attempt resolves with
//!   [`ServeError::Backend`] (including contained worker panics) or
//!   [`ServeError::ChannelClosed`], the ticket strikes the replica's
//!   health and transparently re-submits to another replica — with
//!   exponential backoff plus deterministic jitter, bounded by
//!   [`RetryPolicy::max_attempts`], the request's own deadline, and
//!   [`RetryPolicy::retry_budget`]. Synchronous
//!   [`ServeError::Overloaded`] rejections are forwarded to the other
//!   replicas before any error surfaces to the caller.
//! * **Graceful drain.** [`begin_drain`](Router::begin_drain) closes
//!   admission on every worker (typed [`ServeError::ShuttingDown`])
//!   while queued work flushes; [`shutdown`](Router::shutdown) drains,
//!   joins every worker, and returns the final metrics.

use std::time::{Duration, Instant};

use crate::util::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, Ordering};
use crate::util::sync::Arc;

use super::backend::ExecutionBackend;
use super::error::{ServeError, ServeResult};
use super::metrics::{HealthState, Metrics, MetricsSnapshot};
use super::request::{InferenceResponse, SubmitOptions, Ticket};
use super::server::{Server, ServerConfig};
use crate::util::rng::Xoshiro256;

/// Worker-selection policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Rotate through workers.
    RoundRobin,
    /// Pick the worker with the fewest outstanding requests.
    LeastOutstanding,
    /// Pick the worker whose backend reports the smallest summed
    /// modeled backlog (`shard_depths`), breaking ties on host-side
    /// outstanding counts.
    ModeledBacklog,
}

/// Retry and circuit-breaker policy applied by the router.
///
/// An *attempt* is one admission to one worker; `max_attempts` counts
/// the first try, so `max_attempts == 1` (see [`none`](Self::none))
/// disables re-submission entirely while keeping health tracking
/// active. Backoff before retry `k` (1-based) is
/// `base_backoff · 2^(k−1)`, capped at `max_backoff`, then jittered
/// deterministically into `[½·d, d]` from [`seed`](Self::seed) and the
/// ticket's sequence number — two routers with the same seed replay
/// the same jitter schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total admission attempts per request, including the first
    /// (validated ≥ 1).
    pub max_attempts: u32,
    /// Backoff before the first retry.
    pub base_backoff: Duration,
    /// Ceiling on any single backoff.
    pub max_backoff: Duration,
    /// Wall-clock budget across *all* retries of one request, measured
    /// from first admission; `None` leaves only the request deadline
    /// and `max_attempts` as bounds.
    pub retry_budget: Option<Duration>,
    /// Consecutive failures that eject a replica (Closed → Open).
    pub breaker_threshold: u32,
    /// Time an ejected replica sits out before the router routes one
    /// probe request to it (Open → HalfOpen).
    pub probe_cooldown: Duration,
    /// Seed for the deterministic backoff jitter.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_attempts: 3,
            base_backoff: Duration::from_micros(500),
            max_backoff: Duration::from_millis(50),
            retry_budget: None,
            breaker_threshold: 3,
            probe_cooldown: Duration::from_millis(10),
            seed: 0,
        }
    }
}

impl RetryPolicy {
    /// No re-submission (a single attempt per request); health
    /// tracking and the circuit breaker stay active.
    pub fn none() -> Self {
        Self {
            max_attempts: 1,
            ..Self::default()
        }
    }

    /// Reject contradictory policies before any worker starts.
    pub fn validate(&self) -> Result<(), ServeError> {
        if self.max_attempts == 0 {
            return Err(ServeError::InvalidConfig(
                "RetryPolicy::max_attempts must be at least 1 (the first attempt)".into(),
            ));
        }
        if self.breaker_threshold == 0 {
            return Err(ServeError::InvalidConfig(
                "RetryPolicy::breaker_threshold must be at least 1".into(),
            ));
        }
        Ok(())
    }

    /// Backoff before retry `retry_index` (0-based), jittered into
    /// `[½·d, d]`.
    ///
    /// Public so other supervised loops — notably the
    /// [`RemoteBackend`](crate::transport::RemoteBackend) reconnect
    /// supervisor — share the router's exact backoff semantics instead
    /// of re-deriving them.
    pub fn backoff(&self, retry_index: u32, rng: &mut Xoshiro256) -> Duration {
        let factor = 1u32 << retry_index.min(16);
        let exp = self
            .base_backoff
            .saturating_mul(factor)
            .min(self.max_backoff);
        exp.mul_f64(0.5 + 0.5 * rng.next_f64())
    }
}

/// Circuit-breaker state values (mirrors [`HealthState`]).
const CLOSED: u8 = 0;
const OPEN: u8 = 1;
const HALF_OPEN: u8 = 2;

/// Per-worker breaker: consecutive-failure counter + state machine.
struct Health {
    state: AtomicU8,
    consecutive: AtomicU32,
    /// Microseconds since the router epoch at which the breaker last
    /// opened (probe-cooldown anchor).
    opened_at_us: AtomicU64,
}

impl Health {
    fn new() -> Self {
        Self {
            state: AtomicU8::new(CLOSED),
            consecutive: AtomicU32::new(0),
            opened_at_us: AtomicU64::new(0),
        }
    }

    fn state(&self) -> HealthState {
        match self.state.load(Ordering::Acquire) {
            OPEN => HealthState::Open,
            HALF_OPEN => HealthState::HalfOpen,
            _ => HealthState::Closed,
        }
    }

    /// One observed failure. Ejects at `threshold` consecutive ones; a
    /// failed probe re-ejects. Every transition *into* Open counts as
    /// an ejection.
    fn strike(&self, threshold: u32, now_us: u64, metrics: &Metrics) {
        // Anchor the cooldown clock *before* any transition into Open:
        // the store must be sequenced before the Release CAS that
        // publishes OPEN, or a concurrent `try_probe` could
        // Acquire-load OPEN yet still read a stale (initially 0)
        // anchor, compute a huge elapsed time, and admit a probe the
        // instant the breaker opens — skipping the cooldown entirely.
        // (Found by `loom_probe_never_admitted_before_cooldown`; the
        // side effect — re-anchoring on every strike — just makes the
        // cooldown run from the last observed failure, which is the
        // conservative reading.)
        self.opened_at_us.store(now_us, Ordering::Release);
        let c = self.consecutive.fetch_add(1, Ordering::AcqRel) + 1;
        let opened = if c >= threshold {
            self.state
                .compare_exchange(CLOSED, OPEN, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
        } else {
            false
        };
        // A failed probe re-ejects regardless of the counter.
        let reopened = self
            .state
            .compare_exchange(HALF_OPEN, OPEN, Ordering::AcqRel, Ordering::Acquire)
            .is_ok();
        if opened || reopened {
            metrics.record_ejection();
            metrics.set_health(HealthState::Open);
        }
    }

    /// One observed success. Resets the failure streak; a successful
    /// probe readmits the replica.
    fn ok(&self, metrics: &Metrics) {
        self.consecutive.store(0, Ordering::Release);
        if self
            .state
            .compare_exchange(HALF_OPEN, CLOSED, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            metrics.record_readmission();
            metrics.set_health(HealthState::Closed);
        }
    }

    /// Claim this worker for a single probe if it is Open and its
    /// cooldown has elapsed. At most one caller wins the CAS, so at
    /// most one probe is ever in flight.
    fn try_probe(&self, cooldown: Duration, now_us: u64, metrics: &Metrics) -> bool {
        if self.state.load(Ordering::Acquire) != OPEN {
            return false;
        }
        let opened = self.opened_at_us.load(Ordering::Acquire);
        if now_us.saturating_sub(opened) < cooldown.as_micros() as u64 {
            return false;
        }
        let won = self
            .state
            .compare_exchange(OPEN, HALF_OPEN, Ordering::AcqRel, Ordering::Acquire)
            .is_ok();
        if won {
            metrics.set_health(HealthState::HalfOpen);
        }
        won
    }
}

struct Worker {
    server: Server,
    submitted: AtomicU64,
    metrics: Arc<Metrics>,
    health: Health,
}

impl Worker {
    fn outstanding(&self) -> u64 {
        self.submitted
            .load(Ordering::Relaxed)
            .saturating_sub(self.metrics.requests_fast())
    }
}

/// The router: owns one [`Server`] per backend, plus the health and
/// retry layer between them and the caller.
pub struct Router {
    workers: Vec<Worker>,
    policy: RoutePolicy,
    retry: RetryPolicy,
    next: AtomicU64,
    /// Ticket sequence: decorrelates per-ticket jitter streams.
    ticket_seq: AtomicU64,
    /// Anchor for the breaker's probe-cooldown clock.
    epoch: Instant,
}

impl Router {
    /// Start one server per backend, all with the same serving config,
    /// under the default [`RetryPolicy`] (up to 3 attempts, breaker
    /// threshold 3).
    pub fn start(
        backends: Vec<Box<dyn ExecutionBackend>>,
        config: ServerConfig,
        policy: RoutePolicy,
    ) -> Result<Self, ServeError> {
        Self::start_with_retry(backends, config, policy, RetryPolicy::default())
    }

    /// Start with an explicit retry / circuit-breaker policy
    /// ([`RetryPolicy::none`] restores the PR-5 behaviour of surfacing
    /// every failure to its ticket unretried).
    pub fn start_with_retry(
        backends: Vec<Box<dyn ExecutionBackend>>,
        config: ServerConfig,
        policy: RoutePolicy,
        retry: RetryPolicy,
    ) -> Result<Self, ServeError> {
        if backends.is_empty() {
            return Err(ServeError::InvalidConfig(
                "router needs at least one backend".into(),
            ));
        }
        retry.validate()?;
        let workers = backends
            .into_iter()
            .map(|b| {
                let server = Server::start(b, config)?;
                let metrics = server.metrics_handle();
                Ok(Worker {
                    server,
                    submitted: AtomicU64::new(0),
                    metrics,
                    health: Health::new(),
                })
            })
            .collect::<Result<Vec<_>, ServeError>>()?;
        Ok(Self {
            workers,
            policy,
            retry,
            next: AtomicU64::new(0),
            ticket_seq: AtomicU64::new(0),
            epoch: Instant::now(),
        })
    }

    /// Number of workers.
    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// The configured retry / circuit-breaker policy.
    pub fn retry_policy(&self) -> RetryPolicy {
        self.retry
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Pick a worker index under the configured policy from `eligible`
    /// (non-empty).
    fn pick_among(&self, eligible: &[usize]) -> usize {
        match self.policy {
            RoutePolicy::RoundRobin => {
                eligible[(self.next.fetch_add(1, Ordering::Relaxed) as usize) % eligible.len()]
            }
            RoutePolicy::LeastOutstanding => {
                pick_min(eligible, |i| self.workers[i].outstanding())
            }
            RoutePolicy::ModeledBacklog => pick_min(eligible, |i| {
                let w = &self.workers[i];
                (w.metrics.shard_backlog_fast(), w.outstanding())
            }),
        }
    }

    /// Route one request: probe an ejected-but-cooled-down worker if
    /// any, otherwise pick among healthy workers (falling back to the
    /// full set when every worker is ejected — availability over
    /// purity), skipping `exclude` when an alternative exists.
    fn route(&self, exclude: Option<usize>) -> usize {
        let now_us = self.now_us();
        for (i, w) in self.workers.iter().enumerate() {
            if Some(i) == exclude {
                continue;
            }
            if w.health.try_probe(self.retry.probe_cooldown, now_us, &w.metrics) {
                return i;
            }
        }
        let mut eligible: Vec<usize> = (0..self.workers.len())
            .filter(|&i| {
                Some(i) != exclude && self.workers[i].health.state() == HealthState::Closed
            })
            .collect();
        if eligible.is_empty() {
            // Every alternative is ejected or probing: routing nowhere
            // helps nobody, so route among whatever exists.
            eligible = (0..self.workers.len())
                .filter(|&i| Some(i) != exclude)
                .collect();
        }
        if eligible.is_empty() {
            // Single-worker router retrying against itself.
            return exclude.unwrap_or(0);
        }
        self.pick_among(&eligible)
    }

    /// One admission pass: route, and on [`ServeError::Overloaded`]
    /// forward to each remaining non-ejected worker before giving up.
    /// Non-overload rejections (width, drain, …) surface immediately.
    fn admit(
        &self,
        features: Vec<f32>,
        opts: SubmitOptions,
        exclude: Option<usize>,
    ) -> Result<(usize, Ticket), ServeError> {
        let first = self.route(exclude);
        if self.workers.len() == 1 {
            // Nobody to forward to: move the features instead of
            // cloning them for a scan that cannot happen.
            let t = self.workers[first].server.submit_with(features, opts)?;
            self.workers[first].submitted.fetch_add(1, Ordering::Relaxed);
            return Ok((first, t));
        }
        let mut last_err = match self.workers[first].server.submit_with(features.clone(), opts) {
            Ok(t) => {
                self.workers[first].submitted.fetch_add(1, Ordering::Relaxed);
                return Ok((first, t));
            }
            Err(e @ ServeError::Overloaded { .. }) => e,
            Err(e) => return Err(e),
        };
        for i in 0..self.workers.len() {
            if i == first || Some(i) == exclude {
                continue;
            }
            if self.workers[i].health.state() != HealthState::Closed {
                continue;
            }
            match self.workers[i].server.submit_with(features.clone(), opts) {
                Ok(t) => {
                    self.workers[i].submitted.fetch_add(1, Ordering::Relaxed);
                    return Ok((i, t));
                }
                Err(e @ ServeError::Overloaded { .. }) => last_err = e,
                Err(e) => return Err(e),
            }
        }
        Err(last_err)
    }

    /// Record a success observed on worker `i`.
    fn note_success(&self, i: usize) {
        self.workers[i].health.ok(&self.workers[i].metrics);
    }

    /// Record a failure observed on worker `i` (breaker strike).
    fn note_failure(&self, i: usize) {
        self.workers[i]
            .health
            .strike(self.retry.breaker_threshold, self.now_us(), &self.workers[i].metrics);
    }

    /// Submit with explicit QoS options; returns (first worker index,
    /// ticket). The returned [`RoutedTicket`] transparently retries
    /// [`ServeError::Backend`] / [`ServeError::ChannelClosed`] results
    /// on other replicas within the [`RetryPolicy`]; synchronous
    /// [`ServeError::Overloaded`] rejections are forwarded across
    /// replicas (with backoff between full scans) before surfacing, so
    /// backpressure is only visible once the whole group is saturated.
    pub fn submit_with(
        &self,
        features: Vec<f32>,
        opts: SubmitOptions,
    ) -> Result<(usize, RoutedTicket<'_>), ServeError> {
        let started = Instant::now();
        let abs_deadline = opts.deadline.map(|d| started + d);
        let seq = self.ticket_seq.fetch_add(1, Ordering::Relaxed);
        let mut rng = Xoshiro256::seed_from_u64(self.retry.seed ^ seq.wrapping_mul(0x9E37_79B9));
        // Keep a copy for re-submission only when retries are possible.
        let held = (self.retry.max_attempts > 1).then(|| features.clone());
        let mut attempts = 0u32;
        let mut pending = features;
        loop {
            attempts += 1;
            match self.admit(pending, opts, None) {
                Ok((i, ticket)) => {
                    return Ok((
                        i,
                        RoutedTicket {
                            router: self,
                            worker: i,
                            inner: Some(ticket),
                            features: held,
                            opts,
                            abs_deadline,
                            started,
                            attempts,
                            retries: 0,
                            rng,
                        },
                    ));
                }
                Err(e @ ServeError::Overloaded { .. }) => {
                    let Some(ref kept) = held else { return Err(e) };
                    if attempts >= self.retry.max_attempts {
                        return Err(e);
                    }
                    let wait = self.retry.backoff(attempts - 1, &mut rng);
                    match bounded_backoff(wait, started, abs_deadline, self.retry.retry_budget) {
                        Some(d) => std::thread::sleep(d),
                        None => return Err(e),
                    }
                    pending = kept.clone();
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Submit with default options; returns (worker index, ticket).
    pub fn submit(&self, features: Vec<f32>) -> Result<(usize, RoutedTicket<'_>), ServeError> {
        self.submit_with(features, SubmitOptions::default())
    }

    /// Submit and wait.
    pub fn infer(&self, features: Vec<f32>) -> Result<InferenceResponse, ServeError> {
        let (_, ticket) = self.submit(features)?;
        ticket.wait()
    }

    /// Per-worker outstanding counts (diagnostics).
    pub fn outstanding(&self) -> Vec<u64> {
        self.workers.iter().map(|w| w.outstanding()).collect()
    }

    /// Per-worker circuit-breaker states.
    pub fn health(&self) -> Vec<HealthState> {
        self.workers.iter().map(|w| w.health.state()).collect()
    }

    /// Per-worker live metrics snapshots.
    pub fn metrics(&self) -> Vec<MetricsSnapshot> {
        self.workers.iter().map(|w| w.server.metrics()).collect()
    }

    /// Close admission on every worker (typed
    /// [`ServeError::ShuttingDown`]) while queued work keeps flushing.
    /// Idempotent; [`shutdown`](Self::shutdown) implies it.
    pub fn begin_drain(&self) {
        for w in &self.workers {
            w.server.begin_drain();
        }
    }

    /// Gracefully stop all workers — drain admission, flush queues,
    /// join worker threads — returning their final metrics.
    pub fn shutdown(self) -> Vec<MetricsSnapshot> {
        self.begin_drain();
        self.workers
            .into_iter()
            .map(|w| w.server.shutdown())
            .collect()
    }
}

/// First index of `eligible` minimizing `key` — `min_by_key` keeping
/// the earliest minimum, without the `Option` (the routing paths
/// guarantee a non-empty slice, and the coordinator bans `unwrap`; an
/// empty slice degrades to worker 0 rather than panicking).
fn pick_min<K: Ord>(eligible: &[usize], key: impl Fn(usize) -> K) -> usize {
    let mut it = eligible.iter().copied();
    let Some(mut best) = it.next() else { return 0 };
    let mut best_key = key(best);
    for i in it {
        let k = key(i);
        if k < best_key {
            best = i;
            best_key = k;
        }
    }
    best
}

/// Cap `wait` to what the deadline and retry budget leave; `None`
/// means no time remains and the retry must not happen.
fn bounded_backoff(
    wait: Duration,
    started: Instant,
    abs_deadline: Option<Instant>,
    budget: Option<Duration>,
) -> Option<Duration> {
    let now = Instant::now();
    let mut wait = wait;
    if let Some(d) = abs_deadline {
        if now >= d {
            return None;
        }
        wait = wait.min(d - now);
    }
    if let Some(b) = budget {
        let spent = now.saturating_duration_since(started);
        if spent >= b {
            return None;
        }
        wait = wait.min(b - spent);
    }
    Some(wait)
}

/// Owned handle to one router-managed request: wraps the current
/// attempt's [`Ticket`] and transparently re-submits retryable
/// failures to another replica (see [`Router::submit_with`]).
///
/// Mirrors the [`Ticket`] surface — [`wait`](Self::wait),
/// [`wait_timeout`](Self::wait_timeout), [`try_wait`](Self::try_wait),
/// [`cancel`](Self::cancel) — with retry folded into the waiting
/// methods; `wait_timeout`/`try_wait` take `&mut self` because a retry
/// replaces the inner ticket. Successful responses carry the retry
/// count in [`InferenceResponse::retries`]. Dropping the handle
/// cancels the current attempt if it is still queued, exactly like
/// dropping a [`Ticket`].
pub struct RoutedTicket<'r> {
    router: &'r Router,
    worker: usize,
    inner: Option<Ticket>,
    /// A copy of the features for re-submission; `None` when the
    /// policy allows a single attempt (no copy is kept).
    features: Option<Vec<f32>>,
    opts: SubmitOptions,
    abs_deadline: Option<Instant>,
    started: Instant,
    attempts: u32,
    retries: u32,
    rng: Xoshiro256,
}

/// What to do after observing one attempt's result.
enum Verdict {
    /// Result is final: hand it to the caller.
    Done(ServeResult),
    /// The attempt was retried; keep waiting on the new inner ticket.
    Retried,
}

impl RoutedTicket<'_> {
    /// Server-assigned id of the *current* attempt (a retry re-admits
    /// under a fresh id).
    pub fn id(&self) -> u64 {
        self.inner.as_ref().map(|t| t.id()).unwrap_or(0)
    }

    /// Worker index of the current attempt.
    pub fn worker(&self) -> usize {
        self.worker
    }

    /// Completed transparent retries so far.
    pub fn retries(&self) -> u32 {
        self.retries
    }

    /// Withdraw the current attempt if still queued (see
    /// [`Ticket::cancel`]); no further retries happen for a cancelled
    /// ticket.
    pub fn cancel(&self) -> bool {
        self.inner.as_ref().is_some_and(|t| t.cancel())
    }

    fn remaining_opts(&self, now: Instant) -> Option<SubmitOptions> {
        match self.abs_deadline {
            None => Some(self.opts),
            Some(d) if now >= d => None,
            Some(d) => Some(SubmitOptions {
                deadline: Some(d - now),
                ..self.opts
            }),
        }
    }

    /// Process one attempt's result: feed the health layer, then
    /// either finalize or re-submit. `sleep_cap` bounds the backoff
    /// (for `wait_timeout`, which must not overshoot its window).
    fn settle(&mut self, result: ServeResult, sleep_cap: Option<Duration>) -> Verdict {
        let worker = self.worker;
        match result {
            Ok(mut resp) => {
                self.router.note_success(worker);
                resp.retries = self.retries;
                Verdict::Done(Ok(resp))
            }
            Err(e @ (ServeError::Backend { .. } | ServeError::ChannelClosed)) => {
                self.router.note_failure(worker);
                let Some(ref features) = self.features else {
                    return Verdict::Done(Err(e));
                };
                if self.attempts >= self.router.retry.max_attempts {
                    return Verdict::Done(Err(e));
                }
                let wait = self.router.retry.backoff(self.retries, &mut self.rng);
                let wait = match bounded_backoff(
                    wait,
                    self.started,
                    self.abs_deadline,
                    self.router.retry.retry_budget,
                ) {
                    Some(d) => match sleep_cap {
                        Some(cap) => d.min(cap),
                        None => d,
                    },
                    None => return Verdict::Done(Err(e)),
                };
                if !wait.is_zero() {
                    std::thread::sleep(wait);
                }
                let Some(opts) = self.remaining_opts(Instant::now()) else {
                    return Verdict::Done(Err(e));
                };
                match self.router.admit(features.clone(), opts, Some(worker)) {
                    Ok((j, ticket)) => {
                        // The failed attempt was already settled by the
                        // worker (record_failures); the retry is a pure
                        // router event on the replica that caused it.
                        self.router.workers[worker].metrics.record_retry();
                        self.worker = j;
                        self.inner = Some(ticket);
                        self.attempts += 1;
                        self.retries += 1;
                        Verdict::Retried
                    }
                    // Re-admission failed synchronously (all replicas
                    // overloaded or draining): surface that, it is the
                    // current truth.
                    Err(e2) => Verdict::Done(Err(e2)),
                }
            }
            Err(other) => Verdict::Done(Err(other)),
        }
    }

    /// Block until the request resolves, retrying failed attempts
    /// within the policy. Returns the same typed errors as
    /// [`Ticket::wait`], plus whatever the *last* attempt surfaced
    /// when the retry budget ran out.
    pub fn wait(mut self) -> ServeResult {
        loop {
            // `settle` re-arms `inner` on every retry, so a missing
            // attempt can only mean the handle was already consumed —
            // report the channel closed rather than panicking inside
            // the serving path.
            let Some(ticket) = self.inner.take() else {
                return Err(ServeError::ChannelClosed);
            };
            match self.settle(ticket.wait(), None) {
                Verdict::Done(r) => return r,
                Verdict::Retried => {}
            }
        }
    }

    /// Wait up to `timeout`; `None` means the request (or its current
    /// retry) is still in flight and the ticket remains waitable.
    pub fn wait_timeout(&mut self, timeout: Duration) -> Option<ServeResult> {
        let end = Instant::now() + timeout;
        loop {
            let now = Instant::now();
            let window = end.saturating_duration_since(now);
            let result = self.inner.as_ref()?.wait_timeout(window)?;
            let cap = end.saturating_duration_since(Instant::now());
            let ticket = self.inner.take();
            match self.settle(result, Some(cap)) {
                Verdict::Done(r) => {
                    drop(ticket);
                    return Some(r);
                }
                Verdict::Retried => drop(ticket),
            }
        }
    }

    /// Non-blocking poll; `None` means still in flight. A retryable
    /// failure triggers an immediate (no-backoff) re-submission and
    /// reports "still in flight".
    pub fn try_wait(&mut self) -> Option<ServeResult> {
        let result = self.inner.as_ref()?.try_wait()?;
        let ticket = self.inner.take();
        match self.settle(result, Some(Duration::ZERO)) {
            Verdict::Done(r) => {
                drop(ticket);
                Some(r)
            }
            Verdict::Retried => {
                drop(ticket);
                None
            }
        }
    }
}

// Loom models of the breaker state machine (CI `loom` job). `Health`
// takes the clock as a plain `now_us` argument, so the models pin time
// explicitly and explore only the atomics.
#[cfg(all(test, beanna_loom))]
mod loom_tests {
    use super::*;
    use crate::util::sync::thread;

    /// Regression for the cooldown-anchor ordering: a probe racing the
    /// very strike that opens the breaker must never be admitted while
    /// the cooldown still has time left. With the anchor stored *after*
    /// the state CAS (the pre-fix code), one interleaving Acquire-loads
    /// OPEN but a stale anchor of 0, sees ~1400µs "elapsed", and admits
    /// the probe 100µs into a 500µs cooldown.
    #[test]
    fn loom_probe_never_admitted_before_cooldown() {
        loom::model(|| {
            let h = Arc::new(Health::new());
            let m = Arc::new(Metrics::new());
            let striker = {
                let (h, m) = (Arc::clone(&h), Arc::clone(&m));
                // Threshold 1: this single failure opens the breaker
                // at t = 1000µs.
                thread::spawn(move || h.strike(1, 1_000, &m))
            };
            // Concurrent pick at t = 1400µs: at most 400µs of the
            // 500µs cooldown can have elapsed, whatever the schedule.
            let admitted = h.try_probe(Duration::from_micros(500), 1_400, &m);
            assert!(!admitted, "probe admitted before the cooldown elapsed");
            striker.join().expect("striker thread");
        });
    }

    /// Single-probe admission: once the breaker is Open and cooled
    /// down, exactly one of two concurrent picks wins the
    /// Open→HalfOpen CAS — at most one probe is ever in flight.
    #[test]
    fn loom_single_probe_admission() {
        loom::model(|| {
            let h = Arc::new(Health::new());
            let m = Arc::new(Metrics::new());
            h.strike(1, 0, &m); // open at t = 0
            let prober = {
                let (h, m) = (Arc::clone(&h), Arc::clone(&m));
                thread::spawn(move || h.try_probe(Duration::from_micros(10), 50, &m))
            };
            let a = h.try_probe(Duration::from_micros(10), 50, &m);
            let b = prober.join().expect("prober thread");
            assert!(a ^ b, "exactly one prober must win the CAS");
            assert_eq!(h.state(), HealthState::HalfOpen);
        });
    }

    /// Concurrent strikes crossing the threshold together: the
    /// Closed→Open transition (and its ejection record) happens exactly
    /// once — the consecutive counter is an atomic RMW, so exactly one
    /// striker observes the crossing.
    #[test]
    fn loom_concurrent_strikes_eject_once() {
        loom::model(|| {
            let h = Arc::new(Health::new());
            let m = Arc::new(Metrics::new());
            let striker = {
                let (h, m) = (Arc::clone(&h), Arc::clone(&m));
                thread::spawn(move || h.strike(2, 5, &m))
            };
            h.strike(2, 5, &m);
            striker.join().expect("striker thread");
            assert_eq!(h.state(), HealthState::Open);
            assert_eq!(m.snapshot().ejections, 1);
        });
    }

    /// A probe success racing a failure strike: whichever wins the
    /// HalfOpen exit, the breaker ends in a legal terminal state
    /// (Closed with a readmission, or Open with a re-ejection) — never
    /// stuck HalfOpen with both recorded.
    #[test]
    fn loom_halfopen_exit_is_exclusive() {
        loom::model(|| {
            let h = Arc::new(Health::new());
            let m = Arc::new(Metrics::new());
            h.strike(1, 0, &m);
            assert!(h.try_probe(Duration::ZERO, 1, &m));
            let failer = {
                let (h, m) = (Arc::clone(&h), Arc::clone(&m));
                thread::spawn(move || h.strike(1, 2, &m))
            };
            h.ok(&m);
            failer.join().expect("failing striker");
            let s = m.snapshot();
            match h.state() {
                // ok() won the CAS; the strike's re-ejection CAS lost.
                HealthState::Closed => assert_eq!(s.readmissions, 1),
                // The strike re-ejected first; ok() lost the CAS.
                HealthState::Open => assert_eq!(s.ejections, 2),
                HealthState::HalfOpen => panic!("breaker stuck in HalfOpen"),
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bf16::Matrix;
    use crate::coordinator::backend::{BatchOutput, ReferenceBackend, SimulatorBackend};
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::fault::{FaultInjectingBackend, FaultSpec};
    use crate::nn::{Network, NetworkConfig, Precision};
    use crate::util::par::Parallelism;
    use std::time::Duration;

    fn net(seed: u64) -> Network {
        Network::random(
            &NetworkConfig {
                sizes: vec![784, 16, 10],
                precisions: vec![Precision::Bf16, Precision::Bf16],
                front: None,
            },
            seed,
        )
    }

    fn config() -> ServerConfig {
        ServerConfig {
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            ..Default::default()
        }
    }

    #[test]
    fn round_robin_spreads_evenly() {
        let router = Router::start(
            vec![
                ReferenceBackend::boxed(net(1)),
                ReferenceBackend::boxed(net(1)),
                ReferenceBackend::boxed(net(1)),
            ],
            config(),
            RoutePolicy::RoundRobin,
        )
        .unwrap();
        let mut counts = [0usize; 3];
        let tickets: Vec<_> = (0..30)
            .map(|_| {
                let (i, t) = router.submit(vec![0.1; 784]).unwrap();
                counts[i] += 1;
                t
            })
            .collect();
        for t in tickets {
            assert!(!t.wait().unwrap().logits.is_empty());
        }
        assert_eq!(counts, [10, 10, 10]);
        let metrics = router.shutdown();
        assert_eq!(metrics.iter().map(|m| m.requests).sum::<u64>(), 30);
    }

    #[test]
    fn least_outstanding_avoids_loaded_worker() {
        let router = Router::start(
            vec![ReferenceBackend::boxed(net(1)), ReferenceBackend::boxed(net(2))],
            config(),
            RoutePolicy::LeastOutstanding,
        )
        .unwrap();
        // Submit a burst without receiving; JSQ must not send everything
        // to one worker.
        let tickets: Vec<_> = (0..40)
            .map(|_| router.submit(vec![0.2; 784]).unwrap())
            .collect();
        let mut counts = [0usize; 2];
        for (i, _) in &tickets {
            counts[*i] += 1;
        }
        assert!(counts[0] >= 10 && counts[1] >= 10, "{counts:?}");
        for (_, t) in tickets {
            t.wait().unwrap();
        }
        router.shutdown();
    }

    #[test]
    fn all_workers_produce_identical_results_for_same_weights() {
        let router = Router::start(
            vec![ReferenceBackend::boxed(net(7)), SimulatorBackend::boxed(net(7))],
            config(),
            RoutePolicy::RoundRobin,
        )
        .unwrap();
        // Same image through both workers (round-robin alternates).
        let a = router.infer(vec![0.3; 784]).unwrap();
        let b = router.infer(vec![0.3; 784]).unwrap();
        assert_eq!(a.prediction, b.prediction);
        router.shutdown();
    }

    #[test]
    fn modeled_backlog_without_depths_degrades_to_outstanding() {
        // Reference backends report no shard depths, so every worker
        // scores 0 and the outstanding tie-break decides: a burst must
        // still spread instead of piling on worker 0.
        let router = Router::start(
            vec![ReferenceBackend::boxed(net(3)), ReferenceBackend::boxed(net(4))],
            config(),
            RoutePolicy::ModeledBacklog,
        )
        .unwrap();
        let tickets: Vec<_> = (0..40)
            .map(|_| router.submit(vec![0.2; 784]).unwrap())
            .collect();
        let mut counts = [0usize; 2];
        for (i, _) in &tickets {
            counts[*i] += 1;
        }
        assert!(counts[0] >= 10 && counts[1] >= 10, "{counts:?}");
        for (_, t) in tickets {
            t.wait().unwrap();
        }
        router.shutdown();
    }

    #[test]
    fn empty_router_rejected() {
        let err = Router::start(vec![], config(), RoutePolicy::RoundRobin)
            .err()
            .expect("empty router must be rejected");
        assert!(matches!(err, ServeError::InvalidConfig(_)));
    }

    #[test]
    fn invalid_retry_policy_rejected() {
        let err = Router::start_with_retry(
            vec![ReferenceBackend::boxed(net(1))],
            config(),
            RoutePolicy::RoundRobin,
            RetryPolicy {
                max_attempts: 0,
                ..RetryPolicy::default()
            },
        )
        .err()
        .expect("max_attempts 0 must be rejected");
        assert!(matches!(err, ServeError::InvalidConfig(_)), "{err}");
        assert!(RetryPolicy {
            breaker_threshold: 0,
            ..RetryPolicy::default()
        }
        .validate()
        .is_err());
    }

    #[test]
    fn backoff_is_exponential_capped_and_jittered() {
        let p = RetryPolicy {
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(4),
            ..RetryPolicy::default()
        };
        let mut rng = Xoshiro256::seed_from_u64(1);
        let b0 = p.backoff(0, &mut rng);
        let b1 = p.backoff(1, &mut rng);
        let b9 = p.backoff(9, &mut rng);
        assert!(b0 >= Duration::from_micros(500) && b0 <= Duration::from_millis(1), "{b0:?}");
        assert!(b1 >= Duration::from_millis(1) && b1 <= Duration::from_millis(2), "{b1:?}");
        assert!(b9 <= Duration::from_millis(4), "cap holds: {b9:?}");
        // Deterministic per seed.
        let mut r1 = Xoshiro256::seed_from_u64(9);
        let mut r2 = Xoshiro256::seed_from_u64(9);
        assert_eq!(p.backoff(2, &mut r1), p.backoff(2, &mut r2));
    }

    /// Always fails with a typed error.
    struct AlwaysFails;
    impl ExecutionBackend for AlwaysFails {
        fn run_batch_with(
            &mut self,
            _batch: &Matrix,
            _par: Parallelism,
        ) -> anyhow::Result<BatchOutput> {
            anyhow::bail!("permanently broken")
        }
        fn tag(&self) -> &str {
            "always-fails"
        }
        fn input_width(&self) -> Option<usize> {
            Some(784)
        }
        fn num_classes(&self) -> Option<usize> {
            Some(10)
        }
    }

    #[test]
    fn retry_forwards_backend_failures_to_a_healthy_replica() {
        let router = Router::start(
            vec![Box::new(AlwaysFails), ReferenceBackend::boxed(net(1))],
            ServerConfig {
                policy: BatchPolicy::unbatched(),
                ..Default::default()
            },
            RoutePolicy::RoundRobin,
        )
        .unwrap();
        // Every request succeeds even though worker 0 always fails: the
        // failed attempt is transparently forwarded to worker 1.
        for _ in 0..6 {
            let resp = router.infer(vec![0.2; 784]).unwrap();
            assert!(resp.retries <= 2);
        }
        let m = router.shutdown();
        assert_eq!(m[1].requests, 6, "all work lands on the healthy replica");
        assert!(m[0].failures >= 1);
        assert_eq!(m[0].retries, m[0].failures, "every failure was retried");
    }

    #[test]
    fn without_retry_failures_surface_to_the_ticket() {
        let router = Router::start_with_retry(
            vec![Box::new(AlwaysFails), ReferenceBackend::boxed(net(1))],
            ServerConfig {
                policy: BatchPolicy::unbatched(),
                ..Default::default()
            },
            RoutePolicy::RoundRobin,
            RetryPolicy::none(),
        )
        .unwrap();
        let mut errors = 0;
        for _ in 0..6 {
            if router.infer(vec![0.2; 784]).is_err() {
                errors += 1;
            }
        }
        assert!(errors >= 1, "unretried failures must surface");
        let m = router.shutdown();
        assert_eq!(m[0].retries, 0);
    }

    #[test]
    fn breaker_ejects_probes_and_readmits() {
        // Worker 0 fails its first two batches, then recovers; with
        // threshold 2 the breaker must eject it after the second
        // failure, route a probe after the cooldown, and readmit it.
        let faulty = FaultInjectingBackend::boxed(
            ReferenceBackend::boxed(net(1)),
            FaultSpec {
                fail_first: 2,
                ..FaultSpec::default()
            },
        );
        let retry = RetryPolicy {
            breaker_threshold: 2,
            probe_cooldown: Duration::from_millis(5),
            ..RetryPolicy::default()
        };
        let router = Router::start_with_retry(
            vec![faulty, ReferenceBackend::boxed(net(1))],
            ServerConfig {
                policy: BatchPolicy::unbatched(),
                ..Default::default()
            },
            RoutePolicy::RoundRobin,
            retry,
        )
        .unwrap();
        // Drive until the breaker opens (bounded).
        let mut ejected = false;
        for _ in 0..20 {
            router.infer(vec![0.2; 784]).unwrap();
            if router.health()[0] == HealthState::Open {
                ejected = true;
                break;
            }
        }
        assert!(ejected, "worker 0 must be ejected: {:?}", router.health());
        // While Open it receives no routine traffic.
        let before = router.metrics()[0].failures;
        router.infer(vec![0.2; 784]).unwrap();
        assert_eq!(router.metrics()[0].failures, before, "no traffic while ejected");
        // After the cooldown a probe goes through and readmits it.
        std::thread::sleep(Duration::from_millis(8));
        let mut readmitted = false;
        for _ in 0..20 {
            router.infer(vec![0.2; 784]).unwrap();
            if router.health()[0] == HealthState::Closed
                && router.metrics()[0].readmissions >= 1
            {
                readmitted = true;
                break;
            }
        }
        assert!(readmitted, "worker 0 must be readmitted: {:?}", router.health());
        let m = router.shutdown();
        assert_eq!(m[0].ejections, 1);
        assert_eq!(m[0].readmissions, 1);
        assert_eq!(m[0].health, HealthState::Closed);
        assert_eq!(m[0].failures, 2, "exactly the scripted outage");
    }

    #[test]
    fn drain_closes_admission_and_flushes() {
        let router = Router::start(
            vec![ReferenceBackend::boxed(net(1))],
            config(),
            RoutePolicy::RoundRobin,
        )
        .unwrap();
        let (_, queued) = router.submit(vec![0.1; 784]).unwrap();
        router.begin_drain();
        assert_eq!(
            router.submit(vec![0.1; 784]).unwrap_err(),
            ServeError::ShuttingDown
        );
        assert!(queued.wait().is_ok(), "queued work flushes during drain");
        let m = router.shutdown();
        assert_eq!(m[0].requests, 1);
    }

    #[test]
    fn deadline_bounds_retries() {
        // A single permanently-broken worker with a short deadline:
        // retries must stop at the deadline, not spin max_attempts
        // times past it.
        let router = Router::start_with_retry(
            vec![Box::new(AlwaysFails)],
            ServerConfig {
                policy: BatchPolicy::unbatched(),
                ..Default::default()
            },
            RoutePolicy::RoundRobin,
            RetryPolicy {
                max_attempts: 100,
                base_backoff: Duration::from_millis(20),
                max_backoff: Duration::from_millis(20),
                ..RetryPolicy::default()
            },
        )
        .unwrap();
        let t0 = Instant::now();
        let (_, ticket) = router
            .submit_with(
                vec![0.2; 784],
                SubmitOptions::default().with_deadline(Duration::from_millis(40)),
            )
            .unwrap();
        assert!(ticket.wait().is_err());
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "deadline must bound the retry loop, took {:?}",
            t0.elapsed()
        );
        router.shutdown();
    }
}
