//! Serving metrics: counters + latency distribution, lock-protected and
//! snapshot-able.
//!
//! QoS accounting distinguishes the four ways a request can fail to be
//! served: `failures` (the backend ran and errored, or a stale-width
//! request was rejected worker-side), `rejected` (bounded admission
//! turned it away at submit — it never held a queue slot), `expired`
//! (its deadline passed while queued; dropped at batch formation), and
//! `cancelled` (withdrawn through its ticket before dispatch).

use std::time::Duration;

use super::backend::TransportStats;
use crate::util::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use crate::util::sync::{lock, Mutex};

/// Circuit-breaker state of one replica, as tracked by the router's
/// health layer and surfaced in [`MetricsSnapshot::health`].
///
/// ```text
///            threshold consecutive failures
///   Closed ─────────────────────────────────► Open   (ejected)
///     ▲                                         │
///     │ probe succeeds                          │ cooldown elapsed,
///     │ (readmitted)                            ▼ one probe routed
///     └──────────────────────────────────── HalfOpen
///                                               │ probe fails
///                                               └───────► Open
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HealthState {
    /// Healthy: in the routing rotation (the default).
    #[default]
    Closed,
    /// Ejected: consecutive failures crossed the breaker threshold;
    /// the replica receives no traffic until its probe cooldown
    /// elapses.
    Open,
    /// Probing: exactly one request is in flight to test recovery;
    /// success readmits (→ Closed), failure re-ejects (→ Open).
    HalfOpen,
}

/// Internal accumulating state.
#[derive(Debug, Default)]
struct State {
    requests: u64,
    failures: u64,
    rejected: u64,
    expired: u64,
    cancelled: u64,
    retries: u64,
    ejections: u64,
    readmissions: u64,
    batches: u64,
    batch_rows_sum: u64,
    queue_us: Vec<f64>,
    compute_us: Vec<f64>,
    sim_cycles: u64,
    shard_depths: Option<Vec<u64>>,
    reconnects: u64,
    transport_errors: u64,
    started: Option<std::time::Instant>,
    finished: Option<std::time::Instant>,
}

/// Thread-safe metrics registry owned by the server.
#[derive(Debug)]
pub struct Metrics {
    state: Mutex<State>,
    /// Lock-free mirror of the settled-request count (successes,
    /// failures, expiries, cancellations), for hot-path consumers
    /// (the router's least-outstanding policy).
    requests_fast: AtomicU64,
    /// Lock-free mirror of the latest summed per-shard backlog gauge,
    /// for the router's modeled-backlog policy.
    shard_backlog_fast: AtomicU64,
    /// Circuit-breaker state of the replica these metrics belong to
    /// (written by the router's health layer; [`HealthState::Closed`]
    /// for replicas behind no router).
    health: AtomicU8,
}

/// Immutable view of the metrics at a point in time.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// Requests served successfully.
    pub requests: u64,
    /// Requests that received a typed error on the response channel
    /// (backend faults, or stale-width requests rejected by the worker
    /// after a width re-pin).
    pub failures: u64,
    /// Requests refused at submit time by bounded admission
    /// (`ServeError::Overloaded`); they never held a queue slot.
    pub rejected: u64,
    /// Admitted requests whose deadline passed while queued; dropped
    /// at batch-formation time (`ServeError::DeadlineExceeded`) without
    /// ever reaching the backend.
    pub expired: u64,
    /// Admitted requests withdrawn through their ticket (explicit
    /// `cancel()` or dropping the unresolved ticket) before dispatch.
    pub cancelled: u64,
    /// Failed attempts the router transparently re-submitted to
    /// another replica instead of surfacing to the ticket. Counted on
    /// the replica whose failure *caused* the retry.
    pub retries: u64,
    /// Times the router's circuit breaker ejected this replica from
    /// the routing rotation (Closed → Open).
    pub ejections: u64,
    /// Times a probe succeeded and the router readmitted this replica
    /// (HalfOpen → Closed).
    pub readmissions: u64,
    /// Current circuit-breaker state of this replica
    /// ([`HealthState::Closed`] when no router health layer is
    /// involved).
    pub health: HealthState,
    /// Batches executed.
    pub batches: u64,
    /// Mean rows per batch.
    pub mean_batch: f64,
    /// Queue-delay summary (µs) — includes p50 (`median`) and `p99` —
    /// if any requests were served.
    pub queue_us: Option<crate::util::stats::Summary>,
    /// Compute-latency summary (µs per batch).
    pub compute_us: Option<crate::util::stats::Summary>,
    /// Total simulated device cycles (simulator backend).
    pub sim_cycles: u64,
    /// Per-shard queue depths reported by a multi-array backend after
    /// its most recent batch. For the sharded simulator: modeled cycles
    /// of **remaining work** each shard still owes beyond the device's
    /// issue frontier — an absolute-load gauge that keeps growing with
    /// queued commands even when the device balances its own shards
    /// perfectly. `None` for single-device backends.
    pub shard_depths: Option<Vec<u64>>,
    /// Times this replica's transport re-dialed its worker after a
    /// lost connection (cumulative, reported by remote backends via
    /// [`ExecutionBackend::transport_stats`]; 0 for in-process
    /// replicas). Together with [`transport_errors`], this separates
    /// wire trouble from backend trouble: a replica whose `failures`
    /// climb *with* `transport_errors` has a flaky wire or dead
    /// worker, one whose `failures` climb alone has a faulty backend.
    ///
    /// [`ExecutionBackend::transport_stats`]: super::backend::ExecutionBackend::transport_stats
    /// [`transport_errors`]: Self::transport_errors
    pub reconnects: u64,
    /// Wire-level failures on this replica's transport (read/write
    /// errors, decode failures, checksum mismatches, missed
    /// heartbeats). A worker answering with a typed error frame is a
    /// *backend* fault and counts only in `failures`, not here.
    pub transport_errors: u64,
    /// Wall-clock span from first to last batch.
    pub wall: Duration,
    /// Requests per wall-clock second.
    pub throughput_rps: f64,
}

// Spelled out rather than derived: the sync shim's loom twins don't
// implement `Default`, and this is the only constructor either way.
impl Default for Metrics {
    fn default() -> Self {
        Self {
            state: Mutex::new(State::default()),
            requests_fast: AtomicU64::new(0),
            shard_backlog_fast: AtomicU64::new(0),
            health: AtomicU8::new(0),
        }
    }
}

impl Metrics {
    /// New empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one executed batch.
    pub fn record_batch(
        &self,
        rows: usize,
        queue_us: &[u64],
        compute_us: u64,
        sim_cycles: Option<u64>,
    ) {
        let mut s = lock(&self.state);
        let now = std::time::Instant::now();
        s.started.get_or_insert(now);
        s.finished = Some(now);
        s.requests += rows as u64;
        s.batches += 1;
        s.batch_rows_sum += rows as u64;
        s.queue_us.extend(queue_us.iter().map(|&q| q as f64));
        s.compute_us.push(compute_us as f64);
        s.sim_cycles += sim_cycles.unwrap_or(0);
        self.requests_fast.fetch_add(rows as u64, Ordering::Relaxed);
    }

    /// Record the per-shard queue depths a multi-array backend reported
    /// after a batch (latest value wins — it's a gauge, not a counter).
    pub fn record_shard_depths(&self, depths: Vec<u64>) {
        self.shard_backlog_fast
            .store(depths.iter().sum(), Ordering::Relaxed);
        lock(&self.state).shard_depths = Some(depths);
    }

    /// Record the cumulative wire-health counters a remote backend
    /// reported after a batch (latest value wins — the backend reports
    /// monotonic totals, not deltas). Pure gauge: never settles the
    /// fast answered counter.
    pub fn record_transport_stats(&self, stats: TransportStats) {
        let mut s = lock(&self.state);
        s.reconnects = stats.reconnects;
        s.transport_errors = stats.transport_errors;
    }

    /// Record `rows` requests that received a typed error response
    /// (a failed backend batch, or worker-side stale-width
    /// rejections). Counts toward the fast answered counter (the
    /// requests are no longer outstanding) but not toward `requests`.
    pub fn record_failures(&self, rows: usize) {
        let mut s = lock(&self.state);
        s.failures += rows as u64;
        drop(s);
        self.requests_fast.fetch_add(rows as u64, Ordering::Relaxed);
    }

    /// Record `n` submissions refused by bounded admission. They were
    /// never admitted, so they do **not** settle the fast answered
    /// counter (the router never counted them as outstanding).
    pub fn record_rejected(&self, n: usize) {
        lock(&self.state).rejected += n as u64;
    }

    /// Record `n` admitted requests dropped at batch formation because
    /// their deadline had passed. Settles the fast answered counter.
    pub fn record_expired(&self, n: usize) {
        let mut s = lock(&self.state);
        s.expired += n as u64;
        drop(s);
        self.requests_fast.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Record `n` admitted requests withdrawn through their ticket
    /// before dispatch. Settles the fast answered counter.
    pub fn record_cancelled(&self, n: usize) {
        let mut s = lock(&self.state);
        s.cancelled += n as u64;
        drop(s);
        self.requests_fast.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Record one failed attempt the router re-submitted elsewhere.
    /// The failing attempt already settled the fast answered counter
    /// through [`record_failures`](Self::record_failures), so this is
    /// a pure router-level counter.
    pub fn record_retry(&self) {
        lock(&self.state).retries += 1;
    }

    /// Record one circuit-breaker ejection (Closed → Open).
    pub fn record_ejection(&self) {
        lock(&self.state).ejections += 1;
    }

    /// Record one readmission (a probe succeeded, HalfOpen → Closed).
    pub fn record_readmission(&self) {
        lock(&self.state).readmissions += 1;
    }

    /// Publish the replica's current circuit-breaker state (written by
    /// the router's health layer on every transition).
    pub fn set_health(&self, h: HealthState) {
        self.health.store(h as u8, Ordering::Relaxed);
    }

    /// The replica's current circuit-breaker state.
    pub fn health(&self) -> HealthState {
        match self.health.load(Ordering::Relaxed) {
            1 => HealthState::Open,
            2 => HealthState::HalfOpen,
            _ => HealthState::Closed,
        }
    }

    /// Answered-request count (successes + failures + expiries +
    /// cancellations) without taking the lock.
    pub fn requests_fast(&self) -> u64 {
        self.requests_fast.load(Ordering::Relaxed)
    }

    /// Latest summed per-shard modeled backlog, without taking the
    /// lock (0 until a multi-array backend reports depths). The
    /// router's `ModeledBacklog` policy reads this on every pick.
    pub fn shard_backlog_fast(&self) -> u64 {
        self.shard_backlog_fast.load(Ordering::Relaxed)
    }

    /// Snapshot the current totals.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let s = lock(&self.state);
        let wall = match (s.started, s.finished) {
            (Some(a), Some(b)) => b.duration_since(a),
            _ => Duration::ZERO,
        };
        let throughput = if wall.as_secs_f64() > 0.0 {
            s.requests as f64 / wall.as_secs_f64()
        } else {
            0.0
        };
        MetricsSnapshot {
            requests: s.requests,
            failures: s.failures,
            rejected: s.rejected,
            expired: s.expired,
            cancelled: s.cancelled,
            retries: s.retries,
            ejections: s.ejections,
            readmissions: s.readmissions,
            health: self.health(),
            batches: s.batches,
            mean_batch: if s.batches > 0 {
                s.batch_rows_sum as f64 / s.batches as f64
            } else {
                0.0
            },
            queue_us: if s.queue_us.is_empty() {
                None
            } else {
                Some(crate::util::stats::Summary::of(&s.queue_us))
            },
            compute_us: if s.compute_us.is_empty() {
                None
            } else {
                Some(crate::util::stats::Summary::of(&s.compute_us))
            },
            sim_cycles: s.sim_cycles,
            shard_depths: s.shard_depths.clone(),
            reconnects: s.reconnects,
            transport_errors: s.transport_errors,
            wall,
            throughput_rps: throughput,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_batch(4, &[10, 20, 30, 40], 500, Some(1000));
        m.record_batch(2, &[5, 5], 300, Some(500));
        let s = m.snapshot();
        assert_eq!(s.requests, 6);
        assert_eq!(s.failures, 0);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch - 3.0).abs() < 1e-12);
        assert_eq!(s.sim_cycles, 1500);
        let q = s.queue_us.unwrap();
        assert_eq!(q.n, 6);
        assert_eq!(q.max, 40.0);
        assert!(q.p99 <= q.max && q.p99 >= q.median);
    }

    #[test]
    fn failures_counted_separately_but_settle_outstanding() {
        let m = Metrics::new();
        m.record_batch(2, &[1, 1], 10, None);
        m.record_failures(3);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.failures, 3);
        // The router's outstanding accounting sees all five answered.
        assert_eq!(m.requests_fast(), 5);
    }

    #[test]
    fn qos_counters_settle_outstanding_except_rejections() {
        let m = Metrics::new();
        m.record_expired(2);
        m.record_cancelled(1);
        m.record_rejected(4);
        let s = m.snapshot();
        assert_eq!(s.expired, 2);
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.rejected, 4);
        assert_eq!(s.requests, 0);
        // Expired + cancelled were admitted (outstanding); rejected
        // never were.
        assert_eq!(m.requests_fast(), 3);
    }

    #[test]
    fn empty_snapshot_is_safe() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.rejected, 0);
        assert_eq!(s.expired, 0);
        assert_eq!(s.cancelled, 0);
        assert_eq!(s.retries, 0);
        assert_eq!(s.ejections, 0);
        assert_eq!(s.readmissions, 0);
        assert_eq!(s.health, HealthState::Closed);
        assert!(s.queue_us.is_none());
        assert!(s.shard_depths.is_none());
        assert_eq!(s.reconnects, 0);
        assert_eq!(s.transport_errors, 0);
        assert_eq!(s.throughput_rps, 0.0);
    }

    #[test]
    fn fault_tolerance_counters_are_pure_router_events() {
        let m = Metrics::new();
        m.record_retry();
        m.record_retry();
        m.record_ejection();
        m.record_readmission();
        let s = m.snapshot();
        assert_eq!(s.retries, 2);
        assert_eq!(s.ejections, 1);
        assert_eq!(s.readmissions, 1);
        // None of these settle the outstanding accounting: the failing
        // attempt itself was already counted by record_failures.
        assert_eq!(m.requests_fast(), 0);
    }

    #[test]
    fn health_gauge_round_trips_every_state() {
        let m = Metrics::new();
        assert_eq!(m.health(), HealthState::Closed);
        for h in [HealthState::Open, HealthState::HalfOpen, HealthState::Closed] {
            m.set_health(h);
            assert_eq!(m.health(), h);
            assert_eq!(m.snapshot().health, h);
        }
    }

    #[test]
    fn transport_stats_gauge_keeps_latest_and_stays_pure() {
        let m = Metrics::new();
        m.record_transport_stats(TransportStats {
            reconnects: 1,
            transport_errors: 4,
        });
        m.record_transport_stats(TransportStats {
            reconnects: 2,
            transport_errors: 9,
        });
        let s = m.snapshot();
        // Latest cumulative totals win; wire faults never settle the
        // outstanding accounting (the failed request itself does, via
        // record_failures).
        assert_eq!(s.reconnects, 2);
        assert_eq!(s.transport_errors, 9);
        assert_eq!(m.requests_fast(), 0);
    }

    #[test]
    fn shard_depths_gauge_keeps_latest() {
        let m = Metrics::new();
        m.record_shard_depths(vec![10, 0]);
        assert_eq!(m.shard_backlog_fast(), 10);
        m.record_shard_depths(vec![4, 7]);
        assert_eq!(m.snapshot().shard_depths, Some(vec![4, 7]));
        assert_eq!(m.shard_backlog_fast(), 11);
    }
}

// Loom models of the lock-free mirrors (CI `loom` job). These assert
// the orderings in use today are sound: `Relaxed` is enough because
// both mirrors are single-cell values with no cross-variable invariant
// — the gauge is last-writer-wins and the counter is a pure sum.
#[cfg(all(test, beanna_loom))]
mod loom_tests {
    use super::*;
    use crate::util::sync::{thread, Arc};

    /// Two concurrent gauge writers: whichever interleaving runs, the
    /// lock-free mirror holds one of the two written sums (never a torn
    /// or stale-initial value) and the locked state holds a matching
    /// full vector.
    #[test]
    fn loom_shard_backlog_gauge_is_last_writer_wins() {
        loom::model(|| {
            let m = Arc::new(Metrics::new());
            let writer = {
                let m = Arc::clone(&m);
                thread::spawn(move || m.record_shard_depths(vec![3, 4]))
            };
            m.record_shard_depths(vec![10]);
            writer.join().expect("gauge writer");
            let fast = m.shard_backlog_fast();
            assert!(fast == 7 || fast == 10, "gauge must be one writer's sum, got {fast}");
            let depths = m.snapshot().shard_depths.expect("depths recorded");
            assert!(depths == vec![3, 4] || depths == vec![10]);
        });
    }

    /// Concurrent settlement on both mirror paths (a served batch and a
    /// failed batch): the fast answered counter ends at the exact total
    /// and the locked counters reconcile with it under every schedule.
    #[test]
    fn loom_requests_fast_counts_every_settlement() {
        loom::model(|| {
            let m = Arc::new(Metrics::new());
            let failer = {
                let m = Arc::clone(&m);
                thread::spawn(move || m.record_failures(2))
            };
            m.record_batch(3, &[1, 2, 3], 10, None);
            failer.join().expect("failure recorder");
            assert_eq!(m.requests_fast(), 5);
            let s = m.snapshot();
            assert_eq!(s.requests, 3);
            assert_eq!(s.failures, 2);
        });
    }
}
