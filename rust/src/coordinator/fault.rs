//! Deterministic fault injection: the chaos harness the fault-tolerance
//! layer is built and tested against.
//!
//! [`FaultInjectingBackend`] wraps any [`ExecutionBackend`] and injects
//! the four failure shapes a real deployment sees, each at an
//! independently configured rate drawn from a **seeded** PRNG
//! ([`Xoshiro256`]) — the same seed replays the exact same fault
//! schedule, so chaos tests are reproducible bit-for-bit:
//!
//! * **Typed errors** — `run_batch_with` returns `Err`, which the
//!   server converts to [`ServeError::Backend`](super::error::ServeError::Backend)
//!   on every ticket of the batch (a faulted RPC, a device reset).
//! * **Added latency** — the call sleeps before executing (a slow or
//!   congested replica; exercises deadline and backoff interaction).
//! * **Garbage logits** — the call short-circuits with well-shaped but
//!   meaningless logits (silent data corruption; shape checks cannot
//!   catch it, which is exactly the point — it measures what slips
//!   through).
//! * **Panics** — the call panics (a driver bug, an assertion in
//!   third-party code); the server's `catch_unwind` must contain it.
//!
//! Two deterministic overrides make targeted tests easy:
//! [`FaultSpec::fail_first`] fails the first N calls unconditionally
//! (a replica that comes up broken and then recovers — drives the
//! circuit breaker through eject → probe → readmit on a fixed script)
//! and [`FaultSpec::panic_on_call`] panics on exactly the given call.
//!
//! With every rate at 0 and no overrides, the wrapper is **transparent**
//! — same logits, same declared shape, same `shard_depths` — which the
//! backend-conformance suite asserts for every in-tree backend.

use std::time::Duration;

use anyhow::Result;

use super::backend::{BatchOutput, ExecutionBackend};
use super::error::ServeError;
use crate::bf16::Matrix;
use crate::util::par::Parallelism;
use crate::util::rng::Xoshiro256;

/// Fault configuration: independent rates per failure shape, plus
/// deterministic overrides. All rates are probabilities in `[0, 1]`
/// applied per `run_batch_with` call.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Probability a call returns a typed error.
    pub error_rate: f64,
    /// Probability a call short-circuits with garbage logits.
    pub garbage_rate: f64,
    /// Probability a call panics.
    pub panic_rate: f64,
    /// Probability a call sleeps [`added_latency`](Self::added_latency)
    /// before executing.
    pub latency_rate: f64,
    /// Sleep injected on a latency draw.
    pub added_latency: Duration,
    /// Deterministic outage: the first N calls fail unconditionally
    /// with a typed error (then the configured rates apply).
    pub fail_first: u64,
    /// Deterministic panic: call number N (1-based) panics.
    pub panic_on_call: Option<u64>,
    /// PRNG seed; the whole fault schedule is a pure function of it.
    pub seed: u64,
}

impl Default for FaultSpec {
    /// No faults at all (a transparent wrapper).
    fn default() -> Self {
        Self {
            error_rate: 0.0,
            garbage_rate: 0.0,
            panic_rate: 0.0,
            latency_rate: 0.0,
            added_latency: Duration::ZERO,
            fail_first: 0,
            panic_on_call: None,
            seed: 0,
        }
    }
}

impl FaultSpec {
    /// Typed errors only, at `rate`, from `seed`.
    pub fn errors(rate: f64, seed: u64) -> Self {
        Self {
            error_rate: rate,
            seed,
            ..Self::default()
        }
    }

    /// True when the wrapper injects nothing (pure pass-through).
    pub fn is_transparent(&self) -> bool {
        self.error_rate == 0.0
            && self.garbage_rate == 0.0
            && self.panic_rate == 0.0
            && self.latency_rate == 0.0
            && self.fail_first == 0
            && self.panic_on_call.is_none()
    }

    /// Reject rates outside `[0, 1]`.
    pub fn validate(&self) -> Result<(), ServeError> {
        for (name, rate) in [
            ("error", self.error_rate),
            ("garbage", self.garbage_rate),
            ("panic", self.panic_rate),
            ("latency-rate", self.latency_rate),
        ] {
            if !(0.0..=1.0).contains(&rate) || !rate.is_finite() {
                return Err(ServeError::InvalidConfig(format!(
                    "fault spec: {name} rate {rate} is not in [0, 1]"
                )));
            }
        }
        Ok(())
    }

    /// Parse the CLI's `--fault-spec` syntax: comma-separated
    /// `key=value` pairs. Keys: `error`, `garbage`, `panic`,
    /// `latency-rate` (rates in `[0,1]`), `latency-us` (injected sleep),
    /// `fail-first` (deterministic leading failures), `panic-on-call`
    /// (1-based call number), `seed`.
    ///
    /// ```
    /// use beanna::coordinator::fault::FaultSpec;
    /// let s = FaultSpec::parse("error=0.1,seed=42").unwrap();
    /// assert_eq!(s.error_rate, 0.1);
    /// assert_eq!(s.seed, 42);
    /// ```
    pub fn parse(spec: &str) -> Result<Self, ServeError> {
        let mut out = Self::default();
        let bad = |part: &str, what: &str| {
            ServeError::InvalidConfig(format!("fault spec: {what} in '{part}'"))
        };
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| bad(part, "expected key=value"))?;
            let rate = || value.parse::<f64>().map_err(|_| bad(part, "bad number"));
            let int = || value.parse::<u64>().map_err(|_| bad(part, "bad integer"));
            match key.trim() {
                "error" => out.error_rate = rate()?,
                "garbage" => out.garbage_rate = rate()?,
                "panic" => out.panic_rate = rate()?,
                "latency-rate" => out.latency_rate = rate()?,
                "latency-us" => out.added_latency = Duration::from_micros(int()?),
                "fail-first" => out.fail_first = int()?,
                "panic-on-call" => out.panic_on_call = Some(int()?),
                "seed" => out.seed = int()?,
                other => {
                    return Err(ServeError::InvalidConfig(format!(
                        "fault spec: unknown key '{other}' (known: error, garbage, panic, \
                         latency-rate, latency-us, fail-first, panic-on-call, seed)"
                    )))
                }
            }
        }
        out.validate()?;
        Ok(out)
    }

    /// Same spec with a different seed (per-replica decorrelation).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// What the wrapper has injected so far (observability for tests and
/// the chaos bench).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct InjectionCounts {
    /// Typed errors returned (including `fail_first` ones).
    pub errors: u64,
    /// Garbage-logit short circuits.
    pub garbage: u64,
    /// Panics raised.
    pub panics: u64,
    /// Latency sleeps injected.
    pub delays: u64,
    /// Total `run_batch_with` calls observed.
    pub calls: u64,
}

/// A seedable chaos wrapper around any [`ExecutionBackend`].
///
/// Declared shape (`max_batch`, `input_width`, `num_classes`),
/// `warm`, and `shard_depths` pass straight through to the inner
/// backend; only `run_batch_with` is intercepted. The tag is
/// `faulty-<inner tag>` so injected failures are attributable in logs
/// and [`ServeError::Backend`](super::error::ServeError::Backend)
/// messages.
pub struct FaultInjectingBackend {
    inner: Box<dyn ExecutionBackend>,
    spec: FaultSpec,
    rng: Xoshiro256,
    tag: String,
    counts: InjectionCounts,
}

impl FaultInjectingBackend {
    /// Wrap `inner` under `spec`. The fault schedule is fully
    /// determined by `spec.seed` and the sequence of calls.
    pub fn new(inner: Box<dyn ExecutionBackend>, spec: FaultSpec) -> Self {
        let tag = format!("faulty-{}", inner.tag());
        Self {
            inner,
            rng: Xoshiro256::seed_from_u64(spec.seed),
            spec,
            tag,
            counts: InjectionCounts::default(),
        }
    }

    /// Boxed, ready for `Server`/`Router`/`EngineBuilder::backend`.
    pub fn boxed(inner: Box<dyn ExecutionBackend>, spec: FaultSpec) -> Box<dyn ExecutionBackend> {
        Box::new(Self::new(inner, spec))
    }

    /// Injection counters so far.
    pub fn counts(&self) -> InjectionCounts {
        self.counts
    }

    /// The configured fault spec.
    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }
}

impl ExecutionBackend for FaultInjectingBackend {
    fn run_batch_with(&mut self, batch: &Matrix, par: Parallelism) -> Result<BatchOutput> {
        self.counts.calls += 1;
        let call = self.counts.calls;
        // One draw per failure shape per call, in fixed order, so the
        // schedule for seed S is independent of which rates are zero.
        let d_latency = self.rng.next_f64();
        let d_panic = self.rng.next_f64();
        let d_error = self.rng.next_f64();
        let d_garbage = self.rng.next_f64();
        if self.spec.latency_rate > 0.0 && d_latency < self.spec.latency_rate {
            self.counts.delays += 1;
            std::thread::sleep(self.spec.added_latency);
        }
        if self.spec.panic_on_call == Some(call) {
            self.counts.panics += 1;
            panic!("injected panic on call {call} (panic-on-call)");
        }
        if call <= self.spec.fail_first {
            self.counts.errors += 1;
            anyhow::bail!(
                "injected fault: deterministic outage (call {call} of first {})",
                self.spec.fail_first
            );
        }
        if self.spec.panic_rate > 0.0 && d_panic < self.spec.panic_rate {
            self.counts.panics += 1;
            panic!("injected panic on call {call} (rate {})", self.spec.panic_rate);
        }
        if self.spec.error_rate > 0.0 && d_error < self.spec.error_rate {
            self.counts.errors += 1;
            anyhow::bail!("injected fault on call {call} (rate {})", self.spec.error_rate);
        }
        if self.spec.garbage_rate > 0.0 && d_garbage < self.spec.garbage_rate {
            self.counts.garbage += 1;
            // Well-shaped, meaningless logits: rows match the batch and
            // columns match the declared class count (1 when the inner
            // backend declares none), so shape checks pass — silent
            // corruption by construction.
            let cols = self.inner.num_classes().unwrap_or(1);
            let mut logits = Matrix::zeros(batch.rows, cols);
            for r in 0..batch.rows {
                for v in logits.row_mut(r) {
                    *v = self.rng.uniform(-1.0e3, 1.0e3);
                }
            }
            return Ok(BatchOutput {
                logits,
                sim_cycles: None,
            });
        }
        self.inner.run_batch_with(batch, par)
    }

    fn tag(&self) -> &str {
        &self.tag
    }

    fn max_batch(&self) -> Option<usize> {
        self.inner.max_batch()
    }

    fn input_width(&self) -> Option<usize> {
        self.inner.input_width()
    }

    fn num_classes(&self) -> Option<usize> {
        self.inner.num_classes()
    }

    fn warm(&mut self) {
        self.inner.warm();
    }

    fn shard_depths(&self) -> Option<Vec<u64>> {
        self.inner.shard_depths()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::ReferenceBackend;
    use crate::nn::{Network, NetworkConfig, Precision};

    fn tiny_net() -> Network {
        Network::random(
            &NetworkConfig {
                sizes: vec![16, 8, 4],
                precisions: vec![Precision::Bf16, Precision::Bf16],
                front: None,
            },
            5,
        )
    }

    fn wrapped(spec: FaultSpec) -> FaultInjectingBackend {
        FaultInjectingBackend::new(ReferenceBackend::boxed(tiny_net()), spec)
    }

    #[test]
    fn transparent_at_rate_zero() {
        let x = Matrix::from_vec(3, 16, vec![0.25; 48]).unwrap();
        let mut plain = ReferenceBackend::new(tiny_net());
        let mut faulty = wrapped(FaultSpec::default());
        assert!(faulty.spec().is_transparent());
        for _ in 0..5 {
            let a = plain.run_batch(&x).unwrap();
            let b = faulty.run_batch(&x).unwrap();
            assert_eq!(a.logits, b.logits);
        }
        assert_eq!(faulty.tag(), "faulty-ref");
        assert_eq!(faulty.input_width(), Some(16));
        assert_eq!(faulty.num_classes(), Some(4));
        assert_eq!(faulty.counts().errors, 0);
        assert_eq!(faulty.counts().calls, 5);
    }

    #[test]
    fn fault_schedule_is_deterministic_per_seed() {
        let x = Matrix::from_vec(1, 16, vec![0.5; 16]).unwrap();
        let run = |seed: u64| -> Vec<bool> {
            let mut b = wrapped(FaultSpec::errors(0.5, seed));
            (0..64).map(|_| b.run_batch(&x).is_err()).collect()
        };
        assert_eq!(run(7), run(7), "same seed must replay the same schedule");
        assert_ne!(run(7), run(8), "different seeds must differ");
    }

    #[test]
    fn error_rate_is_roughly_honored() {
        let x = Matrix::from_vec(1, 16, vec![0.5; 16]).unwrap();
        let mut b = wrapped(FaultSpec::errors(0.1, 3));
        let errs = (0..1000).filter(|_| b.run_batch(&x).is_err()).count();
        assert!((50..200).contains(&errs), "10% of 1000 ≈ {errs}");
        assert_eq!(b.counts().errors as usize, errs);
    }

    #[test]
    fn fail_first_is_an_exact_outage() {
        let x = Matrix::from_vec(1, 16, vec![0.5; 16]).unwrap();
        let mut b = wrapped(FaultSpec {
            fail_first: 3,
            ..FaultSpec::default()
        });
        for call in 1..=3 {
            let err = b.run_batch(&x).unwrap_err();
            assert!(err.to_string().contains("outage"), "call {call}: {err}");
        }
        assert!(b.run_batch(&x).is_ok(), "recovers after the outage");
    }

    #[test]
    fn garbage_is_well_shaped_but_wrong() {
        let x = Matrix::from_vec(2, 16, vec![0.5; 32]).unwrap();
        let mut plain = ReferenceBackend::new(tiny_net());
        let mut b = wrapped(FaultSpec {
            garbage_rate: 1.0,
            ..FaultSpec::default()
        });
        let garbage = b.run_batch(&x).unwrap();
        let real = plain.run_batch(&x).unwrap();
        assert_eq!(
            (garbage.logits.rows, garbage.logits.cols),
            (real.logits.rows, real.logits.cols),
            "garbage must pass shape checks"
        );
        assert_ne!(garbage.logits, real.logits, "…but not be the real answer");
        assert_eq!(b.counts().garbage, 1);
    }

    #[test]
    fn panic_on_call_panics_exactly_there() {
        let x = Matrix::from_vec(1, 16, vec![0.5; 16]).unwrap();
        let mut b = wrapped(FaultSpec {
            panic_on_call: Some(2),
            ..FaultSpec::default()
        });
        assert!(b.run_batch(&x).is_ok());
        let x2 = x.clone();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = b.run_batch(&x2);
        }));
        assert!(caught.is_err(), "call 2 must panic");
        assert!(b.run_batch(&x).is_ok(), "call 3 runs again");
    }

    #[test]
    fn latency_injection_sleeps() {
        let x = Matrix::from_vec(1, 16, vec![0.5; 16]).unwrap();
        let mut b = wrapped(FaultSpec {
            latency_rate: 1.0,
            added_latency: Duration::from_millis(5),
            ..FaultSpec::default()
        });
        let t0 = std::time::Instant::now();
        assert!(b.run_batch(&x).is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(5));
        assert_eq!(b.counts().delays, 1);
    }

    #[test]
    fn spec_parses_the_cli_syntax() {
        let s = FaultSpec::parse(
            "error=0.1, garbage=0.05, panic=0.01, latency-us=200, latency-rate=0.5, \
             fail-first=3, panic-on-call=7, seed=42",
        )
        .unwrap();
        assert_eq!(s.error_rate, 0.1);
        assert_eq!(s.garbage_rate, 0.05);
        assert_eq!(s.panic_rate, 0.01);
        assert_eq!(s.added_latency, Duration::from_micros(200));
        assert_eq!(s.latency_rate, 0.5);
        assert_eq!(s.fail_first, 3);
        assert_eq!(s.panic_on_call, Some(7));
        assert_eq!(s.seed, 42);
        assert_eq!(FaultSpec::parse("").unwrap(), FaultSpec::default());
    }

    #[test]
    fn spec_rejects_nonsense() {
        for bad in ["error", "error=x", "bogus=1", "error=1.5", "panic=-0.1"] {
            let err = FaultSpec::parse(bad).unwrap_err();
            assert!(matches!(err, ServeError::InvalidConfig(_)), "{bad}: {err}");
        }
    }

    #[test]
    fn typo_key_error_names_the_offender_and_lists_valid_keys() {
        // The classic one-letter slip: `erorr=0.1`. The typed error
        // must point at the bad key AND enumerate every valid key, so
        // the fix is in the message.
        let err = FaultSpec::parse("erorr=0.1").unwrap_err();
        assert!(matches!(err, ServeError::InvalidConfig(_)));
        let msg = err.to_string();
        assert!(msg.contains("unknown key 'erorr'"), "{msg}");
        for key in [
            "error",
            "garbage",
            "panic",
            "latency-rate",
            "latency-us",
            "fail-first",
            "panic-on-call",
            "seed",
        ] {
            assert!(msg.contains(key), "message must list '{key}': {msg}");
        }
    }
}
