//! Summary statistics shared by the bench harness and report generators.

/// Robust summary of a sample of measurements (e.g. nanoseconds per iter).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Number of samples.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation (n-1 denominator; 0 for n < 2).
    pub std_dev: f64,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
    /// Median (50th percentile, linear interpolation).
    pub median: f64,
    /// 5th percentile.
    pub p05: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile — the tail the serving QoS metrics report
    /// (queue-delay p99 under overload).
    pub p99: f64,
}

impl Summary {
    /// Compute a summary over `samples`. Panics on an empty slice.
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary::of on empty sample set");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|&x| (x - mean).powi(2)).sum::<f64>() / (n as f64 - 1.0)
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self {
            n,
            mean,
            std_dev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p05: percentile_sorted(&sorted, 5.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }

    /// Coefficient of variation (σ/μ); 0 when the mean is 0.
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.std_dev / self.mean
        }
    }
}

/// Percentile with linear interpolation over a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Online mean/variance accumulator (Welford). Used by the power model's
/// toggle-activity tracking where we cannot hold all samples.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    /// New empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one sample.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    /// Number of samples so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean (0 when empty).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1); 0 for fewer than two samples.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std_dev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.std_dev, 0.0);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.p95, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs);
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.variance().sqrt() - s.std_dev).abs() < 1e-12);
    }
}
