//! Persistent worker pool for the matmul hot paths.
//!
//! PR 1's engine spawned and joined OS threads via `std::thread::scope`
//! on **every** kernel call. That is correct and simple, but a
//! heavy-traffic coordinator serving small batches pays the
//! spawn+join cost (tens of microseconds) per request — comparable to
//! the matmul itself at batch 1. This module amortizes it: a process-wide
//! [`WorkerPool`] of parked threads is created once (lazily, sized by
//! [`Parallelism::auto`]) and every subsequent tile dispatch is a
//! queue push + wakeup instead of a `clone(2)`.
//!
//! Design notes:
//!
//! * **Scoped semantics without `'static` jobs.** [`WorkerPool::run_jobs`]
//!   blocks until every submitted job has finished, so jobs may borrow
//!   from the caller's stack. Internally the borrow lifetime is erased
//!   (see the `SAFETY` comment) — the blocking join is what makes that
//!   sound, exactly like `std::thread::scope`.
//! * **Panic-safe join.** A panicking job never takes down a pool
//!   thread: the worker catches the unwind, records the payload, keeps
//!   serving, and the panic is resumed on the *dispatching* thread after
//!   all jobs in the group finish — same observable behaviour as a
//!   panicking `std::thread::scope` child.
//! * **The caller helps.** While waiting, the dispatching thread drains
//!   the queue itself, so a dispatch of `w` jobs reaches concurrency `w`
//!   even when the pool is briefly oversubscribed, and a pool of `P`
//!   threads never idles the calling core.
//! * **Nested dispatch runs inline.** A job that itself calls
//!   `run_jobs` (e.g. a kernel composed of parallel stages) executes the
//!   inner jobs on its own thread — no deadlock, no queue recursion.
//!
//! The serial path of [`crate::util::par::par_tiles_with`] never touches
//! the pool, so bit-exactness of the scalar reference is preserved by
//! construction; the pool only changes *which thread* runs a tile.
//!
//! ```
//! use beanna::util::par::Dispatch;
//! use beanna::util::pool::{par_row_bands, WorkerPool};
//!
//! // Jobs may borrow from the caller's stack (scoped semantics).
//! let inputs = [10u64, 20, 30, 40];
//! let squares = par_row_bands(Dispatch::Pool, 2, inputs.len(), |band| {
//!     band.map(|i| inputs[i] * inputs[i]).collect::<Vec<_>>()
//! });
//! let flat: Vec<u64> = squares.into_iter().flatten().collect();
//! assert_eq!(flat, vec![100, 400, 900, 1600]);
//!
//! // The process-wide pool is created lazily and then reused.
//! assert!(WorkerPool::global().threads() >= 1);
//! ```

use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::OnceLock;

use super::par::{Dispatch, Parallelism};
use super::sync::atomic::{AtomicUsize, Ordering};
use super::sync::{thread, Arc, Condvar, Mutex};

/// A lifetime-erased job plus the completion group it belongs to.
type Task = Box<dyn FnOnce() + Send + 'static>;

/// Shared job queue: pending `(job, group)` pairs + shutdown flag.
struct Queue {
    jobs: Mutex<QueueState>,
    /// Signalled when a job is pushed or shutdown begins.
    available: Condvar,
}

struct QueueState {
    pending: VecDeque<(Task, Arc<Group>)>,
    shutdown: bool,
}

/// Completion tracking for one `run_jobs` call.
struct Group {
    state: Mutex<GroupState>,
    /// Signalled when the last job of the group finishes.
    done: Condvar,
}

struct GroupState {
    remaining: usize,
    /// First panic payload observed in this group, if any.
    panic: Option<Box<dyn std::any::Any + Send>>,
}

#[cfg(not(beanna_loom))]
thread_local! {
    /// True on pool worker threads — used to run nested dispatch inline.
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

// Loom twin: loom's `thread_local!` macro has no const-init form, and
// its instrumented `LocalKey` is what lets the model reset the flag
// between explored executions.
#[cfg(beanna_loom)]
loom::thread_local! {
    /// True on pool worker threads — used to run nested dispatch inline.
    static IN_POOL_WORKER: Cell<bool> = Cell::new(false);
}

/// Hard ceiling on pool growth — a guard against pathological budgets,
/// far above any sane kernel fan-out.
const MAX_POOL_THREADS: usize = 256;

/// A persistent pool of parked worker threads (see module docs).
pub struct WorkerPool {
    queue: Arc<Queue>,
    handles: Mutex<Vec<thread::JoinHandle<()>>>,
    threads: AtomicUsize,
}

impl WorkerPool {
    /// Create a pool with `threads` parked workers (clamped to ≥ 1).
    pub fn new(threads: usize) -> Self {
        let queue = Arc::new(Queue {
            jobs: Mutex::new(QueueState {
                pending: VecDeque::new(),
                shutdown: false,
            }),
            available: Condvar::new(),
        });
        let pool = Self {
            queue,
            handles: Mutex::new(Vec::new()),
            threads: AtomicUsize::new(0),
        };
        pool.ensure_threads(threads.max(1));
        pool
    }

    /// Number of worker threads in this pool.
    pub fn threads(&self) -> usize {
        self.threads.load(Ordering::Acquire)
    }

    /// Grow the pool to at least `n` worker threads (capped at a hard
    /// ceiling). The global pool starts at the auto-sized host budget;
    /// an explicitly larger `Parallelism::fixed(n)` / `--kernel-workers`
    /// request grows it on first use so the configured fan-out is
    /// honored rather than silently capped. Growth is one-time and
    /// monotonic; shrinking never happens (idle workers just park).
    pub fn ensure_threads(&self, n: usize) {
        let n = n.min(MAX_POOL_THREADS);
        if n <= self.threads() {
            return;
        }
        let mut handles = self.handles.lock().unwrap();
        let cur = handles.len();
        for i in cur..n {
            let q = Arc::clone(&self.queue);
            handles.push(
                thread::Builder::new()
                    .name(format!("beanna-pool-{i}"))
                    .spawn(move || worker_loop(&q))
                    .expect("spawn pool worker"),
            );
        }
        if n > cur {
            self.threads.store(n, Ordering::Release);
        }
    }

    /// The process-wide pool, created on first use and sized by
    /// [`Parallelism::auto`] (honors `BEANNA_WORKERS`). Never torn down —
    /// its threads park between dispatches and cost nothing while idle.
    pub fn global() -> &'static WorkerPool {
        static POOL: OnceLock<WorkerPool> = OnceLock::new();
        POOL.get_or_init(|| WorkerPool::new(Parallelism::auto().max_workers()))
    }

    /// Run every job to completion, borrowing from the caller's scope.
    ///
    /// Blocks until all jobs have finished (the scoped-thread contract).
    /// If any job panicked, the first panic is resumed here — after the
    /// whole group has completed, so no job is left running with dangling
    /// borrows.
    pub fn run_jobs<'scope>(&self, mut jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        match jobs.len() {
            0 => return,
            // A single job has nothing to overlap with — run it here.
            1 => {
                (jobs.pop().expect("len checked"))();
                return;
            }
            _ => {}
        }
        // Nested dispatch from inside a pool job: run inline. The outer
        // group's accounting already covers this thread, and queueing
        // could deadlock if every worker did it.
        if IN_POOL_WORKER.with(|f| f.get()) {
            for job in jobs {
                job();
            }
            return;
        }
        let group = Arc::new(Group {
            state: Mutex::new(GroupState {
                remaining: jobs.len(),
                panic: None,
            }),
            done: Condvar::new(),
        });
        {
            let mut q = self.queue.jobs.lock().unwrap();
            for job in jobs {
                // SAFETY: this function does not return (or unwind) until
                // `group.remaining == 0`, i.e. until every job has run to
                // completion — so every borrow captured by the job
                // outlives its execution, exactly as with
                // `std::thread::scope`. The 'static lifetime is a lie the
                // queue needs; the blocking join below makes it sound.
                let job: Task = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Task>(job)
                };
                q.pending.push_back((job, Arc::clone(&group)));
            }
            self.queue.available.notify_all();
        }
        // Help drain the queue while waiting, then park on the group.
        // Stop helping the moment our own group completes — otherwise a
        // finished dispatcher could be held hostage by an arbitrary
        // backlog of other dispatchers' jobs (request tail latency).
        loop {
            if group.state.lock().unwrap().remaining == 0 {
                break;
            }
            let popped = self.queue.jobs.lock().unwrap().pending.pop_front();
            match popped {
                Some((job, g)) => run_one(job, &g),
                None => break,
            }
        }
        let mut st = group.state.lock().unwrap();
        while st.remaining > 0 {
            st = group.done.wait(st).unwrap();
        }
        if let Some(payload) = st.panic.take() {
            drop(st);
            resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut q = self.queue.jobs.lock().unwrap();
            q.shutdown = true;
            self.queue.available.notify_all();
        }
        // Drain the handle list under the lock but join outside it
        // (loom's `Mutex` has no `get_mut`, and joining while holding a
        // lock the workers might need would be a self-inflicted hazard).
        let handles: Vec<_> = std::mem::take(&mut *self.handles.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Worker thread body: pop jobs until shutdown; drain the queue before
/// honouring shutdown so a dropped pool still completes accepted work.
fn worker_loop(q: &Queue) {
    IN_POOL_WORKER.with(|f| f.set(true));
    loop {
        let job = {
            let mut guard = q.jobs.lock().unwrap();
            loop {
                if let Some(j) = guard.pending.pop_front() {
                    break Some(j);
                }
                if guard.shutdown {
                    break None;
                }
                guard = q.available.wait(guard).unwrap();
            }
        };
        match job {
            Some((job, group)) => run_one(job, &group),
            None => return,
        }
    }
}

/// Execute one job, panic-safely, and retire it from its group.
fn run_one(job: Task, group: &Group) {
    let result = catch_unwind(AssertUnwindSafe(job));
    let mut st = group.state.lock().unwrap();
    st.remaining -= 1;
    if let Err(payload) = result {
        if st.panic.is_none() {
            st.panic = Some(payload);
        }
    }
    if st.remaining == 0 {
        group.done.notify_all();
    }
}

/// Run a batch of scoped jobs with the chosen dispatch strategy:
/// the persistent [`WorkerPool`] (default) or spawn-per-call scoped
/// threads (the PR 1 baseline, kept for benchmarking the pool against).
pub fn run_scoped<'scope>(dispatch: Dispatch, jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
    match dispatch {
        Dispatch::Pool => WorkerPool::global().run_jobs(jobs),
        Dispatch::Spawn => {
            std::thread::scope(|s| {
                for job in jobs {
                    s.spawn(job);
                }
            });
        }
    }
}

/// Reconcile a requested fan-out with what [`Dispatch::Pool`] can run
/// concurrently: the global pool **grows** to an explicitly larger
/// budget (so `--kernel-workers 8` on a 2-core host is honored, as the
/// PR 1 spawn engine did), then the request is capped at pool threads
/// plus the helping dispatcher — which only bites at the hard growth
/// ceiling. [`Dispatch::Spawn`] passes through unchanged.
pub fn clamp_to_pool(dispatch: Dispatch, workers: usize) -> usize {
    match dispatch {
        Dispatch::Pool if workers > 1 => {
            let pool = WorkerPool::global();
            pool.ensure_threads(workers);
            workers.min(pool.threads() + 1)
        }
        _ => workers,
    }
}

/// Split `0..rows` into up to `workers` contiguous bands, run `f` on
/// each band (fanned out per `dispatch` when `workers > 1`), and return
/// the per-band results **in row order**. The single-band call on the
/// caller's thread is the serial reference; banding only changes which
/// thread computes a row, so any elementwise `f` is trivially
/// bit-identical across worker counts.
pub fn par_row_bands<T, F>(dispatch: Dispatch, workers: usize, rows: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(std::ops::Range<usize>) -> T + Sync,
{
    if rows == 0 {
        return Vec::new();
    }
    let workers = clamp_to_pool(dispatch, workers.max(1).min(rows));
    if workers <= 1 {
        return vec![f(0..rows)];
    }
    let band = rows.div_ceil(workers);
    let mut out: Vec<Option<T>> = (0..rows.div_ceil(band)).map(|_| None).collect();
    {
        let f = &f;
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out
            .iter_mut()
            .enumerate()
            .map(|(i, slot)| {
                let r0 = i * band;
                let r1 = ((i + 1) * band).min(rows);
                Box::new(move || *slot = Some(f(r0..r1))) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        run_scoped(dispatch, jobs);
    }
    out.into_iter().map(|t| t.expect("band executed")).collect()
}

/// In-place companion to [`par_row_bands`]: split the row-major `data`
/// (`rows × row_len`) into up to `workers` contiguous row bands and run
/// `f(first_row, band)` on each, writing in place. Serves both the
/// tiler's row-band path and the layer epilogue, so the banding math
/// lives in exactly one place.
pub fn par_row_chunks_mut<F>(
    dispatch: Dispatch,
    workers: usize,
    row_len: usize,
    data: &mut [f32],
    f: F,
) where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if row_len == 0 || data.is_empty() {
        return;
    }
    debug_assert_eq!(data.len() % row_len, 0, "data is not whole rows");
    let rows = data.len() / row_len;
    let workers = clamp_to_pool(dispatch, workers.max(1).min(rows));
    if workers <= 1 {
        f(0, data);
        return;
    }
    let band = rows.div_ceil(workers);
    let f = &f;
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = data
        .chunks_mut(band * row_len)
        .enumerate()
        .map(|(i, chunk)| Box::new(move || f(i * band, chunk)) as Box<dyn FnOnce() + Send + '_>)
        .collect();
    run_scoped(dispatch, jobs);
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Band-fill through a private pool must cover every element exactly
    /// once, and the pool must be reusable across dispatches.
    #[test]
    fn pool_runs_scoped_jobs_and_is_reusable() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.threads(), 3);
        for round in 0..5u32 {
            let mut out = vec![0u32; 64];
            {
                let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out
                    .chunks_mut(16)
                    .enumerate()
                    .map(|(i, chunk)| {
                        Box::new(move || {
                            for (j, v) in chunk.iter_mut().enumerate() {
                                *v = round + (i * 16 + j) as u32;
                            }
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.run_jobs(jobs);
            }
            let want: Vec<u32> = (0..64).map(|j| round + j).collect();
            assert_eq!(out, want, "round {round}");
        }
    }

    #[test]
    fn single_and_empty_dispatches_run_inline() {
        let pool = WorkerPool::new(2);
        pool.run_jobs(Vec::new());
        let mut hit = false;
        {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                vec![Box::new(|| hit = true) as Box<dyn FnOnce() + Send + '_>];
            pool.run_jobs(jobs);
        }
        assert!(hit);
    }

    #[test]
    fn panicking_job_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(|| {}) as Box<dyn FnOnce() + Send + '_>,
                Box::new(|| panic!("tile kernel exploded")) as Box<dyn FnOnce() + Send + '_>,
            ];
            pool.run_jobs(jobs);
        }));
        assert!(caught.is_err(), "panic must reach the dispatcher");
        // The pool must still serve jobs after a panic.
        let mut ok = [false, false];
        {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = ok
                .iter_mut()
                .map(|slot| Box::new(move || *slot = true) as Box<dyn FnOnce() + Send + '_>)
                .collect();
            pool.run_jobs(jobs);
        }
        assert_eq!(ok, [true, true]);
    }

    #[test]
    fn nested_dispatch_runs_inline_without_deadlock() {
        let pool = WorkerPool::new(1); // one worker forces the inline path
        let mut results = vec![0usize; 4];
        {
            let inner: &std::sync::Mutex<&mut [usize]> =
                &std::sync::Mutex::new(results.as_mut_slice());
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
                .map(|outer| {
                    Box::new(move || {
                        // A job dispatching its own jobs must not wait on
                        // the (busy) single worker.
                        let sub: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
                            .map(|j| {
                                Box::new(move || {
                                    inner.lock().unwrap()[outer * 2 + j] = outer * 2 + j + 1;
                                })
                                    as Box<dyn FnOnce() + Send + '_>
                            })
                            .collect();
                        WorkerPool::global().run_jobs(sub);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_jobs(jobs);
        }
        assert_eq!(results, vec![1, 2, 3, 4]);
    }

    #[test]
    fn par_row_bands_covers_rows_in_order_on_both_dispatches() {
        for dispatch in [Dispatch::Pool, Dispatch::Spawn] {
            for rows in [0usize, 1, 5, 7, 16] {
                for workers in [1usize, 2, 3, 16] {
                    let bands =
                        par_row_bands(dispatch, workers, rows, |r| r.collect::<Vec<usize>>());
                    let flat: Vec<usize> = bands.into_iter().flatten().collect();
                    let want: Vec<usize> = (0..rows).collect();
                    assert_eq!(flat, want, "rows={rows} workers={workers} {dispatch:?}");
                }
            }
        }
    }

    #[test]
    fn pool_grows_to_explicit_budgets_and_never_shrinks() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.threads(), 1);
        pool.ensure_threads(3);
        assert_eq!(pool.threads(), 3);
        pool.ensure_threads(2); // never shrinks
        assert_eq!(pool.threads(), 3);
        // The grown workers must actually serve jobs.
        let mut out = vec![0u8; 6];
        {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out
                .chunks_mut(2)
                .map(|c| Box::new(move || c.fill(1)) as Box<dyn FnOnce() + Send + '_>)
                .collect();
            pool.run_jobs(jobs);
        }
        assert_eq!(out, vec![1; 6]);
    }

    #[test]
    fn clamp_honors_explicit_pool_budgets_and_spawn() {
        // Spawn dispatch is never capped by the pool.
        assert_eq!(clamp_to_pool(Dispatch::Spawn, 64), 64);
        assert_eq!(clamp_to_pool(Dispatch::Pool, 1), 1);
        // Pool dispatch grows the global pool to the request, so an
        // explicit small budget comes back unchanged.
        assert_eq!(clamp_to_pool(Dispatch::Pool, 3), 3);
    }

    #[test]
    fn global_pool_is_a_singleton() {
        let a = WorkerPool::global() as *const WorkerPool;
        let b = WorkerPool::global() as *const WorkerPool;
        assert_eq!(a, b);
        assert!(WorkerPool::global().threads() >= 1);
    }
}

// Loom models (CI `loom` job: RUSTFLAGS="--cfg beanna_loom"
// cargo test --release --lib loom_). These use *local* pools, never
// `WorkerPool::global()` — loom objects must not leak across explored
// executions, so a process-wide `OnceLock` pool is off-limits here.
#[cfg(all(test, beanna_loom))]
mod loom_tests {
    use super::*;

    /// The queue/caller-assist drain: under every interleaving of the
    /// worker thread and the helping dispatcher, each job of a dispatch
    /// runs exactly once and `run_jobs` does not return until all of
    /// them have (the scoped-borrow contract the lifetime-erasing
    /// transmute depends on).
    #[test]
    fn loom_drain_runs_each_job_exactly_once() {
        loom::model(|| {
            let pool = WorkerPool::new(1);
            let ran = Arc::new(AtomicUsize::new(0));
            {
                let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
                    .map(|_| {
                        let ran = Arc::clone(&ran);
                        Box::new(move || {
                            ran.fetch_add(1, Ordering::Relaxed);
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.run_jobs(jobs);
            }
            // run_jobs has returned: every job must already be done —
            // a late completion after return would be a dangling borrow.
            assert_eq!(ran.load(Ordering::Relaxed), 2);
        });
    }

    /// Nested dispatch: a job that itself calls `run_jobs` must
    /// complete under every schedule — inline on a pool worker (the
    /// `IN_POOL_WORKER` fast path), or through the queue when the
    /// helping dispatcher picked the outer job up — and every inner
    /// job still runs exactly once.
    #[test]
    fn loom_nested_dispatch_completes_inline_or_queued() {
        loom::model(|| {
            let pool = WorkerPool::new(1);
            let ran = Arc::new(AtomicUsize::new(0));
            {
                let pool_ref = &pool;
                let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
                    .map(|_| {
                        let ran = Arc::clone(&ran);
                        Box::new(move || {
                            let inner: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
                                .map(|_| {
                                    let ran = Arc::clone(&ran);
                                    Box::new(move || {
                                        ran.fetch_add(1, Ordering::Relaxed);
                                    })
                                        as Box<dyn FnOnce() + Send + '_>
                                })
                                .collect();
                            pool_ref.run_jobs(inner);
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.run_jobs(jobs);
            }
            assert_eq!(ran.load(Ordering::Relaxed), 4);
        });
    }

    /// Shutdown drains accepted work: jobs queued before the pool is
    /// dropped still run (the worker honours `shutdown` only after the
    /// queue is empty), under every wakeup ordering.
    #[test]
    fn loom_drop_completes_accepted_work() {
        loom::model(|| {
            let ran = Arc::new(AtomicUsize::new(0));
            {
                let pool = WorkerPool::new(1);
                let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
                    .map(|_| {
                        let ran = Arc::clone(&ran);
                        Box::new(move || {
                            ran.fetch_add(1, Ordering::Relaxed);
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.run_jobs(jobs);
                // Pool dropped here: shutdown + join must not lose work.
            }
            assert_eq!(ran.load(Ordering::Relaxed), 2);
        });
    }
}
