//! Criterion-style micro/macro-benchmark harness (the vendored crate set
//! has no `criterion`; `cargo bench` targets use this with
//! `harness = false`).
//!
//! Measurement protocol, modeled on criterion's:
//! 1. **Warmup** — run the closure repeatedly for `warmup` wall time.
//! 2. **Calibration** — choose an inner iteration count so one sample
//!    takes ≈ `target_sample_time`.
//! 3. **Sampling** — collect `samples` timed samples, each of the inner
//!    iteration count, and report robust statistics per iteration.
//!
//! Results are printed in a fixed-width table and optionally appended to a
//! CSV file for the EXPERIMENTS.md logs.

use std::hint::black_box;
use std::io::Write as _;
use std::time::{Duration, Instant};

use super::stats::Summary;

/// Re-export so bench targets only import from this module.
pub use std::hint::black_box as bb;

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Wall-clock warmup budget.
    pub warmup: Duration,
    /// Number of timed samples.
    pub samples: usize,
    /// Target duration of one sample (inner loop auto-sized to hit this).
    pub target_sample_time: Duration,
    /// Optional CSV path to append `name,mean_ns,median_ns,p05,p95,n`.
    pub csv_path: Option<String>,
}

impl Default for BenchConfig {
    fn default() -> Self {
        // Modest defaults: end-to-end simulator benches are heavyweight.
        Self {
            warmup: Duration::from_millis(300),
            samples: 12,
            target_sample_time: Duration::from_millis(120),
            csv_path: None,
        }
    }
}

impl BenchConfig {
    /// Fast settings for CI/self-test runs.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            samples: 5,
            target_sample_time: Duration::from_millis(30),
            csv_path: None,
        }
    }
}

/// One benchmark's result, in nanoseconds per iteration.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Per-iteration timing statistics (ns).
    pub ns: Summary,
    /// Inner iterations per sample used.
    pub iters_per_sample: u64,
}

impl BenchResult {
    /// Mean throughput in iterations/second.
    pub fn iters_per_sec(&self) -> f64 {
        1e9 / self.ns.mean
    }
}

/// The harness. Create one per bench binary, call [`Harness::bench`]
/// repeatedly, then [`Harness::finish`].
pub struct Harness {
    config: BenchConfig,
    results: Vec<BenchResult>,
}

impl Harness {
    /// New harness. Honors `BEANNA_BENCH_QUICK=1` for CI-speed runs.
    pub fn new(mut config: BenchConfig) -> Self {
        if std::env::var("BEANNA_BENCH_QUICK").as_deref() == Ok("1") {
            let csv = config.csv_path.take();
            config = BenchConfig::quick();
            config.csv_path = csv;
        }
        Self {
            config,
            results: Vec::new(),
        }
    }

    /// Run one benchmark: `f` is the measured closure; its return value is
    /// black-boxed so the optimizer cannot elide the work.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> BenchResult {
        // Warmup + crude single-iteration estimate.
        let warmup_start = Instant::now();
        let mut warmup_iters: u64 = 0;
        while warmup_start.elapsed() < self.config.warmup {
            black_box(f());
            warmup_iters += 1;
        }
        let est_ns = (warmup_start.elapsed().as_nanos() as f64 / warmup_iters as f64).max(1.0);

        // Size the inner loop for the target sample time.
        let iters =
            ((self.config.target_sample_time.as_nanos() as f64 / est_ns).ceil() as u64).max(1);

        let mut samples_ns = Vec::with_capacity(self.config.samples);
        for _ in 0..self.config.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            samples_ns.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }

        let result = BenchResult {
            name: name.to_string(),
            ns: Summary::of(&samples_ns),
            iters_per_sample: iters,
        };
        self.report_line(&result);
        self.results.push(result.clone());
        result
    }

    fn report_line(&self, r: &BenchResult) {
        println!(
            "{:<44} {:>14} {:>14} {:>14}  (cv {:>5.1}%, {} iters/sample)",
            r.name,
            fmt_ns(r.ns.mean),
            fmt_ns(r.ns.median),
            fmt_ns(r.ns.p95),
            r.ns.cv() * 100.0,
            r.iters_per_sample,
        );
        if let Some(path) = &self.config.csv_path {
            if let Ok(mut fh) = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
            {
                let _ = writeln!(
                    fh,
                    "{},{:.1},{:.1},{:.1},{:.1},{}",
                    r.name, r.ns.mean, r.ns.median, r.ns.p05, r.ns.p95, r.ns.n
                );
            }
        }
    }

    /// Print the header line for the results table.
    pub fn header(title: &str) {
        println!("\n== {title} ==");
        println!(
            "{:<44} {:>14} {:>14} {:>14}",
            "benchmark", "mean", "median", "p95"
        );
    }

    /// Consume the harness, returning all results.
    pub fn finish(self) -> Vec<BenchResult> {
        self.results
    }
}

/// Human-format nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut h = Harness::new(BenchConfig {
            warmup: Duration::from_millis(5),
            samples: 3,
            target_sample_time: Duration::from_millis(2),
            csv_path: None,
        });
        let r = h.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..100 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.ns.mean > 0.0);
        assert_eq!(h.finish().len(), 1);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains(" s"));
    }
}
