//! Runtime kernel dispatch: CPU-feature detection → best packed-GEMM kernel.
//!
//! The packed hot paths ([`crate::bf16::packed`] and [`crate::binary`])
//! each have one portable scalar reference kernel plus optional
//! SIMD variants (AVX2 on x86-64, NEON on aarch64). This module is the
//! single seam that decides which one runs:
//!
//! 1. a process-wide programmatic override set by [`force`]
//!    (the `--kernel` CLI flag and the test sweeps use this), else
//! 2. the `BEANNA_KERNEL` environment variable
//!    (`scalar | avx2 | neon | auto`), else
//! 3. [`KernelIsa::detect`] — the best ISA the running CPU supports.
//!
//! Requesting an ISA the CPU (or build target) lacks is never an error:
//! the request falls back to [`KernelIsa::detect`] with a one-time
//! stderr warning, mirroring how `BEANNA_WORKERS` handles malformed
//! values. This keeps `BEANNA_KERNEL=avx2` in a CI matrix safe on any
//! runner.
//!
//! Every kernel behind this seam is **bit-identical** to the scalar
//! reference (see `rust/README.md` §Performance for the contract), so
//! switching kernels — even mid-process — never changes results, only
//! throughput. That is what makes a process-global override safe.
//!
//! ```
//! use beanna::util::dispatch::{self, KernelIsa};
//!
//! // The active ISA is always one the CPU actually supports.
//! assert!(dispatch::active().available());
//! // The scalar floor exists everywhere and uses the [k][4] panel layout.
//! assert!(KernelIsa::Scalar.available());
//! assert_eq!(KernelIsa::Scalar.bf16_lanes(), 4);
//! ```

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Once, OnceLock};

/// Instruction-set architectures the packed kernels are specialised for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelIsa {
    /// Portable scalar reference (the bit-exactness oracle). `[k][4]`
    /// bf16 panels, `u64::count_ones` binary reduction.
    Scalar,
    /// 256-bit x86-64 path: 8-lane `mul+add` bf16 panels (`[k][8]`),
    /// nibble-LUT (Mula) popcount over 256-bit XOR lanes.
    Avx2,
    /// 128-bit aarch64 path: 4-lane bf16 panels (`[k][4]`), scalar
    /// binary reduction (aarch64 `count_ones` already lowers to
    /// `CNT`+`ADDV`).
    Neon,
}

impl KernelIsa {
    /// All known ISAs, in preference order (best last).
    pub const ALL: [KernelIsa; 3] = [KernelIsa::Scalar, KernelIsa::Neon, KernelIsa::Avx2];

    /// Short lowercase tag, as accepted by `BEANNA_KERNEL` and used in
    /// bench keys (`bf16_avx2_gops`, ...).
    pub fn tag(self) -> &'static str {
        match self {
            KernelIsa::Scalar => "scalar",
            KernelIsa::Avx2 => "avx2",
            KernelIsa::Neon => "neon",
        }
    }

    /// Parse a `BEANNA_KERNEL` / `--kernel` value. `Ok(None)` means
    /// `auto` (defer to [`KernelIsa::detect`]).
    pub fn parse(s: &str) -> Result<Option<KernelIsa>, ()> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" | "" => Ok(None),
            "scalar" => Ok(Some(KernelIsa::Scalar)),
            "avx2" => Ok(Some(KernelIsa::Avx2)),
            "neon" => Ok(Some(KernelIsa::Neon)),
            _ => Err(()),
        }
    }

    /// Whether the running CPU (and build target) can execute this
    /// ISA's kernels.
    pub fn available(self) -> bool {
        match self {
            KernelIsa::Scalar => true,
            KernelIsa::Avx2 => avx2_available(),
            // NEON is baseline on aarch64; we never runtime-probe it.
            KernelIsa::Neon => cfg!(target_arch = "aarch64"),
        }
    }

    /// The best available ISA on this machine (cached after first call).
    pub fn detect() -> KernelIsa {
        static DETECTED: OnceLock<KernelIsa> = OnceLock::new();
        *DETECTED.get_or_init(|| {
            if KernelIsa::Avx2.available() {
                KernelIsa::Avx2
            } else if KernelIsa::Neon.available() {
                KernelIsa::Neon
            } else {
                KernelIsa::Scalar
            }
        })
    }

    /// Panel width (output columns interleaved per k step) the bf16
    /// packed kernel for this ISA expects. [`crate::bf16::PackedWeights`]
    /// records the width it was packed with; the dispatcher only takes
    /// a SIMD fast path when the layout matches.
    pub fn bf16_lanes(self) -> usize {
        match self {
            KernelIsa::Scalar => 4,
            KernelIsa::Avx2 => 8,
            KernelIsa::Neon => 4,
        }
    }
}

fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("popcnt")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

// Process-wide override state: 0 = no override, else 1 + discriminant.
const OVR_NONE: u8 = 0;
const OVR_SCALAR: u8 = 1;
const OVR_AVX2: u8 = 2;
const OVR_NEON: u8 = 3;

static OVERRIDE: AtomicU8 = AtomicU8::new(OVR_NONE);

fn encode(isa: Option<KernelIsa>) -> u8 {
    match isa {
        None => OVR_NONE,
        Some(KernelIsa::Scalar) => OVR_SCALAR,
        Some(KernelIsa::Avx2) => OVR_AVX2,
        Some(KernelIsa::Neon) => OVR_NEON,
    }
}

fn decode(v: u8) -> Option<KernelIsa> {
    match v {
        OVR_SCALAR => Some(KernelIsa::Scalar),
        OVR_AVX2 => Some(KernelIsa::Avx2),
        OVR_NEON => Some(KernelIsa::Neon),
        _ => None,
    }
}

/// Programmatically pin the kernel ISA for the whole process
/// (overrides `BEANNA_KERNEL`); `None` restores auto-detection.
///
/// Because all kernels are bit-identical, flipping this concurrently
/// with running inference is safe: in-flight matmuls finish on
/// whichever kernel they dispatched, with the same results.
pub fn force(isa: Option<KernelIsa>) {
    OVERRIDE.store(encode(isa), Ordering::SeqCst);
}

/// Parse-and-[`force`] a CLI-style value (`scalar|avx2|neon|auto`).
/// Returns the human-readable error for unknown values.
pub fn force_named(value: &str) -> Result<(), String> {
    match KernelIsa::parse(value) {
        Ok(isa) => {
            force(isa);
            Ok(())
        }
        Err(()) => Err(format!(
            "invalid kernel '{value}': expected scalar | avx2 | neon | auto"
        )),
    }
}

/// The `BEANNA_KERNEL` request, parsed once per process. Malformed
/// values warn once and behave as `auto`.
fn env_request() -> Option<KernelIsa> {
    static ENV: OnceLock<Option<KernelIsa>> = OnceLock::new();
    *ENV.get_or_init(|| match std::env::var("BEANNA_KERNEL") {
        Ok(raw) => match KernelIsa::parse(&raw) {
            Ok(isa) => isa,
            Err(()) => {
                eprintln!(
                    "beanna: ignoring invalid BEANNA_KERNEL='{raw}' \
                     (expected scalar | avx2 | neon | auto); using auto"
                );
                None
            }
        },
        Err(_) => None,
    })
}

/// Resolve the ISA the next dispatched matmul will use:
/// [`force`] override > `BEANNA_KERNEL` > [`KernelIsa::detect`].
///
/// An unavailable request falls back to [`KernelIsa::detect`] after a
/// one-time stderr warning — never a panic, never a hard error.
pub fn active() -> KernelIsa {
    let requested = match decode(OVERRIDE.load(Ordering::SeqCst)) {
        Some(isa) => Some(isa),
        None => env_request(),
    };
    match requested {
        Some(isa) if isa.available() => isa,
        Some(isa) => {
            static WARN: Once = Once::new();
            WARN.call_once(|| {
                eprintln!(
                    "beanna: requested kernel '{}' is not available on this CPU; \
                     falling back to '{}'",
                    isa.tag(),
                    KernelIsa::detect().tag()
                );
            });
            KernelIsa::detect()
        }
        None => KernelIsa::detect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_all_tags_and_auto() {
        assert_eq!(KernelIsa::parse("auto"), Ok(None));
        assert_eq!(KernelIsa::parse(""), Ok(None));
        assert_eq!(KernelIsa::parse("scalar"), Ok(Some(KernelIsa::Scalar)));
        assert_eq!(KernelIsa::parse("AVX2"), Ok(Some(KernelIsa::Avx2)));
        assert_eq!(KernelIsa::parse(" neon "), Ok(Some(KernelIsa::Neon)));
        assert_eq!(KernelIsa::parse("sse9"), Err(()));
    }

    #[test]
    fn tags_roundtrip_through_parse() {
        for isa in KernelIsa::ALL {
            assert_eq!(KernelIsa::parse(isa.tag()), Ok(Some(isa)));
        }
    }

    #[test]
    fn override_encoding_roundtrips() {
        assert_eq!(decode(encode(None)), None);
        for isa in KernelIsa::ALL {
            assert_eq!(decode(encode(Some(isa))), Some(isa));
        }
    }

    #[test]
    fn detect_is_available_and_scalar_always_is() {
        assert!(KernelIsa::detect().available());
        assert!(KernelIsa::Scalar.available());
    }

    #[test]
    fn lane_widths_match_kernel_contracts() {
        assert_eq!(KernelIsa::Scalar.bf16_lanes(), 4);
        assert_eq!(KernelIsa::Avx2.bf16_lanes(), 8);
        assert_eq!(KernelIsa::Neon.bf16_lanes(), 4);
    }

    #[test]
    fn force_named_rejects_unknown_with_usage() {
        let err = force_named("sse42").unwrap_err();
        assert!(err.contains("sse42") && err.contains("auto"));
        // State is untouched by a failed parse.
        assert!(active().available());
    }
}
