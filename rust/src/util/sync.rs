//! Sync-primitive shim: the single import point for every
//! concurrency-critical module, so the same code can be *model-checked*.
//!
//! In normal builds this re-exports `std::sync` / `std::thread`
//! verbatim — zero cost, zero behaviour change. Under
//! `RUSTFLAGS="--cfg beanna_loom"` (the CI `loom` job) the re-exports
//! switch to [loom](https://docs.rs/loom)'s instrumented twins, and the
//! `loom_*` unit tests in [`util::pool`](crate::util::pool),
//! [`coordinator::request`](crate::coordinator),
//! [`coordinator::metrics`](crate::coordinator::Metrics), and the
//! router's breaker exhaustively explore every interleaving of the
//! state machines built on these primitives.
//!
//! The committed manifest stays std-only: `loom` is `cargo add`ed by
//! the CI job (same pattern as `pjrt-typecheck`), and the cfg is
//! declared in `[lints.rust] unexpected_cfgs`, so offline builds never
//! see it.
//!
//! What deliberately stays `std` even under loom: `mpsc` channels,
//! `Instant` deadlines, and `OnceLock` globals — the loom tests model
//! the slot/breaker/queue state machines, which take clocks as plain
//! `now_us` arguments and never touch channels.
//!
//! ```
//! use beanna::util::sync::{lock, Mutex};
//!
//! let m = Mutex::new(1);
//! *lock(&m) += 1;
//! assert_eq!(*lock(&m), 2);
//! ```

#[cfg(not(beanna_loom))]
pub use std::sync::atomic;
#[cfg(not(beanna_loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard};
#[cfg(not(beanna_loom))]
pub use std::thread;

#[cfg(beanna_loom)]
pub use loom::sync::atomic;
#[cfg(beanna_loom)]
pub use loom::sync::{Arc, Condvar, Mutex, MutexGuard};
#[cfg(beanna_loom)]
pub use loom::thread;

/// Lock a mutex, recovering the guard if a previous holder panicked.
///
/// The serving stack protects plain accumulating state (metrics
/// counters, queue vectors) with its mutexes; a panicked holder can at
/// worst have torn a statistics update, which must not take the whole
/// coordinator down with a poison panic. This is also the
/// `coordinator`/`transport` idiom the repo linter (`cargo run -p
/// xtask -- lint`) enforces in place of `.lock().unwrap()`.
#[cfg(not(beanna_loom))]
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Loom twin of [`lock`]: loom mutexes never observe a poisoning
/// panic mid-model, so a failure here is a test-harness bug.
#[cfg(beanna_loom)]
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().expect("loom mutex poisoned")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lock_gives_exclusive_access() {
        let m = Mutex::new(vec![1, 2]);
        lock(&m).push(3);
        assert_eq!(*lock(&m), vec![1, 2, 3]);
    }

    #[cfg(not(beanna_loom))]
    #[test]
    fn lock_recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(0u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().expect("first lock");
            panic!("poison the mutex");
        })
        .join();
        // A plain `.lock().unwrap()` would now panic; `lock` recovers.
        *lock(&m) += 1;
        assert_eq!(*lock(&m), 1);
    }
}
