//! Miniature property-based testing framework (the vendored crate set has
//! no `proptest`/`quickcheck`).
//!
//! A property is a closure from a [`Gen`] (seeded random source with shape
//! helpers) to `Result<(), String>`. [`check`] runs it over many seeds and
//! reports the first failing seed + message, so failures are reproducible
//! by construction:
//!
//! ```
//! use beanna::util::prop::{check, Gen};
//! check("reverse twice is identity", 200, |g: &mut Gen| {
//!     let xs = g.vec_f32(0..64, -10.0, 10.0);
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     if xs == ys { Ok(()) } else { Err(format!("mismatch: {xs:?}")) }
//! });
//! ```

use std::ops::Range;

use super::rng::Xoshiro256;

/// Random value source handed to properties; wraps the PRNG with
/// shape-generation helpers tuned for this crate's domains.
pub struct Gen {
    rng: Xoshiro256,
    /// The seed of this case (printed on failure).
    pub seed: u64,
}

impl Gen {
    /// New generator for a given case seed.
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Xoshiro256::seed_from_u64(seed),
            seed,
        }
    }

    /// Direct access to the underlying PRNG.
    pub fn rng(&mut self) -> &mut Xoshiro256 {
        &mut self.rng
    }

    /// usize in `range` (half-open).
    pub fn usize_in(&mut self, range: Range<usize>) -> usize {
        assert!(range.end > range.start);
        range.start + self.rng.below(range.end - range.start)
    }

    /// f32 uniform in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform(lo, hi)
    }

    /// Random bool.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector of f32 with random length in `len` and values in `[lo, hi)`.
    pub fn vec_f32(&mut self, len: Range<usize>, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.rng.uniform(lo, hi)).collect()
    }

    /// Vector of ±1.0 signs of length `n`.
    pub fn signs(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rng.sign()).collect()
    }

    /// A "nasty" f32: mixes ordinary values with zeros, subnormal-ish,
    /// huge, and exact-power-of-two values to probe rounding edges.
    /// (Never NaN/Inf — the hardware datapath flushes those upstream.)
    pub fn nasty_f32(&mut self) -> f32 {
        match self.rng.below(8) {
            0 => 0.0,
            1 => -0.0,
            2 => self.rng.uniform(-1e-30, 1e-30),
            3 => self.rng.uniform(-3e30, 3e30),
            4 => (2.0f32).powi(self.rng.below(60) as i32 - 30),
            5 => -(2.0f32).powi(self.rng.below(60) as i32 - 30),
            _ => self.rng.uniform(-100.0, 100.0),
        }
    }

    /// Matrix dims (rows, cols) bounded for fast property runs.
    pub fn dims(&mut self, max: usize) -> (usize, usize) {
        (self.usize_in(1..max + 1), self.usize_in(1..max + 1))
    }
}

/// Run `cases` random cases of `property`. Panics with the failing seed
/// and message on the first failure. Base seed can be pinned via
/// `BEANNA_PROP_SEED` for replaying a failure.
pub fn check<F>(name: &str, cases: u64, mut property: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base: u64 = std::env::var("BEANNA_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xBEA77A);
    for i in 0..cases {
        let seed = base.wrapping_add(i).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut gen = Gen::new(seed);
        if let Err(msg) = property(&mut gen) {
            panic!(
                "property '{name}' failed on case {i} (seed {seed:#x}):\n  {msg}\n\
                 replay with BEANNA_PROP_SEED={base} (case index {i})"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum commutative", 100, |g| {
            let a = g.f32_in(-5.0, 5.0);
            let b = g.f32_in(-5.0, 5.0);
            if a + b == b + a {
                Ok(())
            } else {
                Err("f32 add not commutative?!".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always fails", 10, |_| Err("boom".into()));
    }

    #[test]
    fn gen_ranges_respected() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let k = g.usize_in(3..9);
            assert!((3..9).contains(&k));
            let x = g.f32_in(-2.0, 2.0);
            assert!((-2.0..2.0).contains(&x));
            let (r, c) = g.dims(20);
            assert!(r >= 1 && r <= 20 && c >= 1 && c <= 20);
        }
    }

    #[test]
    fn signs_are_pm_one() {
        let mut g = Gen::new(2);
        let v = g.signs(256);
        assert!(v.iter().all(|&x| x == 1.0 || x == -1.0));
        assert!(v.iter().any(|&x| x == 1.0) && v.iter().any(|&x| x == -1.0));
    }
}
