//! Self-contained utility substrates.
//!
//! The build environment vendors only a minimal crate set (no `rand`,
//! `clap`, `criterion`, `proptest`, or `serde`), so this module provides
//! small, well-tested in-tree replacements:
//!
//! * [`rng`] — SplitMix64 / xoshiro256** PRNGs with normal/uniform helpers.
//! * [`args`] — a tiny declarative CLI argument parser.
//! * [`bench`] — a criterion-style measurement harness (warmup, iters,
//!   robust statistics).
//! * [`prop`] — a miniature property-based testing framework with
//!   shrinking-free counterexample reporting.
//! * [`stats`] — summary statistics shared by `bench` and the reports.
//! * [`dispatch`] — runtime CPU-feature detection routing the packed
//!   GEMMs to the best kernel (scalar / AVX2 / NEON), with the
//!   `BEANNA_KERNEL` override surface.
//! * [`par`] — output tiling for the matmul hot paths (no `rayon`),
//!   with a work-size-aware worker heuristic.
//! * [`pool`] — the persistent worker pool the tiles dispatch to
//!   (parked threads, panic-safe join; spawn-per-call kept as a
//!   benchmark baseline).
//! * [`sync`] — the sync-primitive shim (`std::sync` normally, `loom`
//!   under `--cfg beanna_loom`) that makes the pool, request lifecycle,
//!   breaker, and metrics model-checkable.

pub mod args;
pub mod bench;
pub mod dispatch;
pub mod par;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
