//! Deterministic pseudo-random number generation.
//!
//! The vendored crate set has no `rand`, so we implement the two standard
//! small PRNGs used throughout the crate:
//!
//! * [`SplitMix64`] — used for seeding and cheap hashing-style streams.
//! * [`Xoshiro256`] — xoshiro256**, the workhorse generator for weights,
//!   synthetic data, and property tests. Passes BigCrush; more than
//!   adequate for simulation workloads.
//!
//! All generators are fully deterministic from their seed so every
//! experiment in EXPERIMENTS.md is reproducible bit-for-bit.

/// SplitMix64 (Steele, Lea, Flood 2014). Primarily a seed expander.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 pseudo-random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 (Blackman & Vigna 2018).
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64, per the reference implementation's guidance
    /// (avoids the all-zero state and decorrelates nearby seeds).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next 64 pseudo-random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32 pseudo-random bits (high half — better quality for **).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in `[0, n)` (Lemire's method, unbiased in practice
    /// for the ranges we use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply trick over 64-bit draws.
        let x = self.next_u64();
        (((x as u128) * (n as u128)) >> 64) as usize
    }

    /// Standard normal via Box–Muller (we draw in pairs and cache one).
    pub fn normal(&mut self) -> f32 {
        // Non-cached variant: the simulator calls this in bulk through
        // `normal_vec`, so per-call caching complexity isn't worth it.
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// `n` iid standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        let mut v = Vec::with_capacity(n);
        let mut i = 0;
        while i + 2 <= n {
            let u1 = self.next_f64().max(1e-300);
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            v.push((r * c) as f32);
            v.push((r * s) as f32);
            i += 2;
        }
        if i < n {
            v.push(self.normal());
        }
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random sign in {-1.0, +1.0}.
    #[inline]
    pub fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the published algorithm.
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_seeds() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(1);
        let mut c = Xoshiro256::seed_from_u64(2);
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let cv: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(av, bv);
        assert_ne!(av, cv);
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Xoshiro256::seed_from_u64(99);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
            let y = r.uniform(-3.0, 5.0);
            assert!((-3.0..5.0).contains(&y));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Xoshiro256::seed_from_u64(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::seed_from_u64(42);
        let v = r.normal_vec(100_000);
        let mean: f64 = v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
        let var: f64 =
            v.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / v.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
