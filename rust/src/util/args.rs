//! Minimal declarative CLI argument parser (the vendored crate set has no
//! `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! typed accessors with defaults, and auto-generated `--help` text.
//!
//! ```
//! use beanna::util::args::ArgSpec;
//! let spec = ArgSpec::new("demo", "demo tool")
//!     .flag("verbose", "print more")
//!     .opt("batch", "256", "batch size");
//! let parsed = spec
//!     .parse_from(vec!["--batch".into(), "64".into(), "--verbose".into()])
//!     .unwrap();
//! assert!(parsed.flag("verbose"));
//! assert_eq!(parsed.get_usize("batch").unwrap(), 64);
//! ```

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

/// One declared option.
#[derive(Debug, Clone)]
struct Decl {
    name: String,
    default: Option<String>,
    help: String,
    is_flag: bool,
}

/// Declarative specification of a command's arguments.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    command: String,
    about: String,
    decls: Vec<Decl>,
}

impl ArgSpec {
    /// New spec for `command` with a one-line description.
    pub fn new(command: &str, about: &str) -> Self {
        Self {
            command: command.to_string(),
            about: about.to_string(),
            decls: Vec::new(),
        }
    }

    /// Declare a boolean `--name` flag (default false).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.decls.push(Decl {
            name: name.to_string(),
            default: None,
            help: help.to_string(),
            is_flag: true,
        });
        self
    }

    /// Declare a `--name <value>` option with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.decls.push(Decl {
            name: name.to_string(),
            default: Some(default.to_string()),
            help: help.to_string(),
            is_flag: false,
        });
        self
    }

    /// Declare a required `--name <value>` option (no default).
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.decls.push(Decl {
            name: name.to_string(),
            default: None,
            help: help.to_string(),
            is_flag: false,
        });
        self
    }

    /// Render `--help` text.
    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.command, self.about);
        for d in &self.decls {
            let left = if d.is_flag {
                format!("  --{}", d.name)
            } else if let Some(def) = &d.default {
                format!("  --{} <v> (default {})", d.name, def)
            } else {
                format!("  --{} <v> (required)", d.name)
            };
            s.push_str(&format!("{left:<40} {}\n", d.help));
        }
        s
    }

    /// Parse a token list (not including argv[0]).
    pub fn parse_from(&self, tokens: Vec<String>) -> Result<Parsed> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: BTreeMap<String, bool> = BTreeMap::new();
        let mut positional = Vec::new();
        for d in &self.decls {
            if d.is_flag {
                flags.insert(d.name.clone(), false);
            } else if let Some(def) = &d.default {
                values.insert(d.name.clone(), def.clone());
            }
        }

        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                bail!("{}", self.help_text());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let decl = self
                    .decls
                    .iter()
                    .find(|d| d.name == name)
                    .ok_or_else(|| anyhow!("unknown option --{name}\n{}", self.help_text()))?;
                if decl.is_flag {
                    if inline_val.is_some() {
                        bail!("flag --{name} takes no value");
                    }
                    flags.insert(name, true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow!("option --{name} needs a value"))?,
                    };
                    values.insert(name, val);
                }
            } else {
                positional.push(tok);
            }
        }

        // Required options must be present.
        for d in &self.decls {
            if !d.is_flag && d.default.is_none() && !values.contains_key(&d.name) {
                bail!("missing required option --{}\n{}", d.name, self.help_text());
            }
        }

        Ok(Parsed {
            values,
            flags,
            positional,
        })
    }
}

/// Parsed argument values with typed accessors.
#[derive(Debug, Clone)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    /// Positional (non-option) arguments in order.
    pub positional: Vec<String>,
}

impl Parsed {
    /// Flag value (false when undeclared).
    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    /// Raw string value.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Value parsed as usize.
    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.values
            .get(name)
            .ok_or_else(|| anyhow!("option --{name} not set"))?
            .parse()
            .with_context(|| format!("--{name} must be an unsigned integer"))
    }

    /// Value parsed as u64.
    pub fn get_u64(&self, name: &str) -> Result<u64> {
        self.values
            .get(name)
            .ok_or_else(|| anyhow!("option --{name} not set"))?
            .parse()
            .with_context(|| format!("--{name} must be an unsigned integer"))
    }

    /// Value parsed as f64.
    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.values
            .get(name)
            .ok_or_else(|| anyhow!("option --{name} not set"))?
            .parse()
            .with_context(|| format!("--{name} must be a number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("t", "test")
            .flag("verbose", "v")
            .opt("batch", "256", "b")
            .req("model", "m")
    }

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_defaults_and_required() {
        let p = spec().parse_from(v(&["--model", "hybrid"])).unwrap();
        assert_eq!(p.get_usize("batch").unwrap(), 256);
        assert_eq!(p.get("model"), Some("hybrid"));
        assert!(!p.flag("verbose"));
    }

    #[test]
    fn parses_equals_form_and_flags() {
        let p = spec()
            .parse_from(v(&["--batch=32", "--verbose", "--model=fp", "pos1"]))
            .unwrap();
        assert_eq!(p.get_usize("batch").unwrap(), 32);
        assert!(p.flag("verbose"));
        assert_eq!(p.positional, vec!["pos1".to_string()]);
    }

    #[test]
    fn rejects_unknown_and_missing() {
        assert!(spec().parse_from(v(&["--nope", "--model", "x"])).is_err());
        assert!(spec().parse_from(v(&[])).is_err()); // model required
        assert!(spec().parse_from(v(&["--model"])).is_err()); // needs value
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(spec()
            .parse_from(v(&["--verbose=yes", "--model", "x"]))
            .is_err());
    }

    #[test]
    fn help_lists_options() {
        let h = spec().help_text();
        assert!(h.contains("--batch"));
        assert!(h.contains("--model"));
        assert!(h.contains("required"));
    }
}
