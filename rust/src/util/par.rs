//! Scoped-thread parallel execution for the dense/binary matmul kernels.
//!
//! The vendored crate set has no `rayon`, so this module provides the
//! one primitive the hot paths need: partition a row-major output matrix
//! into disjoint tiles and run a tile kernel on `std::thread::scope`
//! workers. Two split shapes are used:
//!
//! * **Row bands** (batch ≥ workers): each worker gets a contiguous band
//!   of output rows and writes it in place — zero copies.
//! * **Column bands** (small batch, wide output): each worker computes
//!   all rows of a column range into a private scratch tile; the caller
//!   thread scatters the tiles after the join. This is what lets a
//!   batch-1 request still fan out across cores.
//!
//! **Bit-exactness contract:** the tile kernel receives `(row_range,
//! col_range, tile)` and must compute each output element exactly as the
//! serial kernel would — the partition only changes *which thread*
//! computes an element, never the per-element accumulation order. Every
//! parallel kernel in this crate is asserted bit-identical to its serial
//! counterpart by `tests/integration_par_kernels.rs`.

use std::ops::Range;

/// How many worker threads the kernels may use.
///
/// `Parallelism` is a *cap*, resolved lazily against the host: the
/// actual worker count for one kernel invocation also scales with the
/// amount of work (see [`Parallelism::workers_for`]) so tiny matmuls
/// never pay thread-spawn overhead.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    /// Maximum worker threads; `0` = resolve from the host
    /// (`BEANNA_WORKERS` env var, else `available_parallelism`).
    max_workers: usize,
}

impl Parallelism {
    /// Single-threaded execution (the scalar reference behaviour).
    pub fn serial() -> Self {
        Self { max_workers: 1 }
    }

    /// Exactly `n` workers at most (`n` is clamped to ≥ 1).
    pub fn fixed(n: usize) -> Self {
        Self {
            max_workers: n.max(1),
        }
    }

    /// Resolve from the host at call time: the `BEANNA_WORKERS` env var
    /// if set, else `std::thread::available_parallelism`.
    pub fn auto() -> Self {
        Self { max_workers: 0 }
    }

    /// The resolved worker cap for this configuration.
    pub fn max_workers(&self) -> usize {
        if self.max_workers > 0 {
            return self.max_workers;
        }
        if let Ok(s) = std::env::var("BEANNA_WORKERS") {
            if let Ok(n) = s.trim().parse::<usize>() {
                if n > 0 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }

    /// Worker count for a kernel doing `ops` scalar inner-loop steps
    /// (MACs for the float kernels, packed-word XOR-popcounts for the
    /// binary kernel). Each worker must have at least
    /// [`MIN_OPS_PER_WORKER`] steps, so small problems stay serial.
    pub fn workers_for(&self, ops: usize) -> usize {
        (ops / MIN_OPS_PER_WORKER).clamp(1, self.max_workers())
    }
}

impl Default for Parallelism {
    fn default() -> Self {
        Self::auto()
    }
}

/// Minimum inner-loop steps per worker before spawning pays off
/// (~tens of microseconds of work against ~tens of microseconds of
/// spawn+join).
pub const MIN_OPS_PER_WORKER: usize = 32 * 1024;

/// Run `kernel` over the `rows × cols` row-major output `out`, split
/// across up to `workers` scoped threads.
///
/// `kernel(row_range, col_range, tile)` must fill `tile` — a row-major
/// `row_range.len() × col_range.len()` buffer (pre-zeroed) — with the
/// output sub-matrix for those ranges, computing each element exactly as
/// it would for the full `0..rows, 0..cols` call.
///
/// With `workers <= 1` (or an output too small to split) the kernel is
/// invoked once on the calling thread with the full range — this is the
/// serial path and the behavioural reference.
pub fn par_tiles<K>(workers: usize, rows: usize, cols: usize, out: &mut [f32], kernel: K)
where
    K: Fn(Range<usize>, Range<usize>, &mut [f32]) + Sync,
{
    assert_eq!(out.len(), rows * cols, "output buffer size mismatch");
    let workers = workers.max(1).min(rows.max(1) * cols.max(1));
    if workers == 1 || rows == 0 || cols == 0 {
        kernel(0..rows, 0..cols, out);
        return;
    }
    if rows >= workers {
        // Row bands, written in place.
        let band_rows = rows.div_ceil(workers);
        std::thread::scope(|s| {
            for (i, band) in out.chunks_mut(band_rows * cols).enumerate() {
                let r0 = i * band_rows;
                let range = r0..r0 + band.len() / cols;
                let k = &kernel;
                s.spawn(move || k(range, 0..cols, band));
            }
        });
    } else if cols >= workers {
        // Column bands through private scratch tiles.
        let band_cols = cols.div_ceil(workers);
        let mut bands: Vec<(Range<usize>, Vec<f32>)> = (0..cols.div_ceil(band_cols))
            .map(|i| {
                let c0 = i * band_cols;
                let c1 = (c0 + band_cols).min(cols);
                (c0..c1, vec![0.0f32; rows * (c1 - c0)])
            })
            .collect();
        std::thread::scope(|s| {
            for (range, tile) in bands.iter_mut() {
                let range = range.clone();
                let tile = tile.as_mut_slice();
                let k = &kernel;
                s.spawn(move || k(0..rows, range, tile));
            }
        });
        for (range, tile) in &bands {
            let w = range.len();
            for r in 0..rows {
                out[r * cols + range.start..r * cols + range.end]
                    .copy_from_slice(&tile[r * w..(r + 1) * w]);
            }
        }
    } else {
        // Output too small to split usefully.
        kernel(0..rows, 0..cols, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A deterministic per-element function so any partition must
    /// reproduce the serial result exactly.
    fn fill(rows: Range<usize>, cols: Range<usize>, tile: &mut [f32]) {
        let w = cols.len();
        for (ti, r) in rows.clone().enumerate() {
            for (tj, c) in cols.clone().enumerate() {
                tile[ti * w + tj] = (r * 1000 + c) as f32;
            }
        }
    }

    fn reference(rows: usize, cols: usize) -> Vec<f32> {
        let mut out = vec![0.0; rows * cols];
        fill(0..rows, 0..cols, &mut out);
        out
    }

    #[test]
    fn serial_path_covers_everything() {
        let (rows, cols) = (7, 5);
        let mut out = vec![0.0; rows * cols];
        par_tiles(1, rows, cols, &mut out, fill);
        assert_eq!(out, reference(rows, cols));
    }

    #[test]
    fn row_split_matches_serial() {
        for rows in [4usize, 7, 8, 9, 32] {
            let cols = 5;
            let mut out = vec![0.0; rows * cols];
            par_tiles(4, rows, cols, &mut out, fill);
            assert_eq!(out, reference(rows, cols), "rows={rows}");
        }
    }

    #[test]
    fn col_split_matches_serial() {
        // rows < workers forces the column-band path.
        for cols in [8usize, 9, 17, 64] {
            let rows = 2;
            let mut out = vec![0.0; rows * cols];
            par_tiles(8, rows, cols, &mut out, fill);
            assert_eq!(out, reference(rows, cols), "cols={cols}");
        }
    }

    #[test]
    fn tiny_outputs_fall_back_to_serial() {
        let mut out = vec![0.0; 4];
        par_tiles(16, 2, 2, &mut out, fill);
        assert_eq!(out, reference(2, 2));
        let mut empty: Vec<f32> = vec![];
        par_tiles(4, 0, 3, &mut empty, fill);
    }

    #[test]
    fn parallelism_heuristics() {
        assert_eq!(Parallelism::serial().max_workers(), 1);
        assert_eq!(Parallelism::fixed(3).max_workers(), 3);
        assert_eq!(Parallelism::fixed(0).max_workers(), 1);
        assert!(Parallelism::auto().max_workers() >= 1);
        // Small work stays serial; big work scales to the cap.
        let p = Parallelism::fixed(8);
        assert_eq!(p.workers_for(100), 1);
        assert_eq!(p.workers_for(MIN_OPS_PER_WORKER * 3), 3);
        assert_eq!(p.workers_for(usize::MAX / 2), 8);
    }
}
