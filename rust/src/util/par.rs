//! Parallel execution config + tiling for the dense/binary matmul kernels.
//!
//! The vendored crate set has no `rayon`, so this module provides the
//! one primitive the hot paths need: partition a row-major output matrix
//! into disjoint tiles and run a tile kernel on worker threads. Two
//! split shapes are used:
//!
//! * **Row bands** (batch ≥ workers): each worker gets a contiguous band
//!   of output rows and writes it in place — zero copies.
//! * **Column bands** (small batch, wide output): each worker computes
//!   all rows of a column range into a private scratch tile; the caller
//!   thread scatters the tiles after the join. This is what lets a
//!   batch-1 request still fan out across cores.
//!
//! Since PR 2 the workers are not spawned per call: tiles are dispatched
//! to the persistent process-wide [`crate::util::pool::WorkerPool`]
//! ([`Dispatch::Pool`], the default). The PR 1 spawn-per-call scoped
//! threads are kept as [`Dispatch::Spawn`] so the probes can measure the
//! pool against them.
//!
//! **Bit-exactness contract:** the tile kernel receives `(row_range,
//! col_range, tile)` and must compute each output element exactly as the
//! serial kernel would — the partition (and the dispatch strategy) only
//! changes *which thread* computes an element, never the per-element
//! accumulation order. Every parallel kernel in this crate is asserted
//! bit-identical to its serial counterpart by
//! `tests/integration_par_kernels.rs`.
//!
//! Column bands can additionally be aligned to the dispatched kernel's
//! panel width ([`par_tiles_aligned`]) so a split never cuts a SIMD
//! lane group mid-panel — alignment affects throughput only, never
//! results (the kernels handle unaligned edges exactly).
//!
//! ```
//! use beanna::util::par::{par_tiles, Parallelism};
//!
//! // Fill a 4×6 output from a per-element rule; any split must agree.
//! let (rows, cols) = (4, 6);
//! let mut out = vec![0.0f32; rows * cols];
//! par_tiles(3, rows, cols, &mut out, |rr, cc, tile| {
//!     let w = cc.len();
//!     for (ti, r) in rr.clone().enumerate() {
//!         for (tj, c) in cc.clone().enumerate() {
//!             tile[ti * w + tj] = (r * 10 + c) as f32;
//!         }
//!     }
//! });
//! assert_eq!(out[2 * cols + 1], 21.0); // row 2, col 1
//!
//! // Work-size-aware budget: tiny problems never pay dispatch cost.
//! let p = Parallelism::fixed(8);
//! assert_eq!(p.workers_for(100), 1);
//! ```

use std::ops::Range;

use super::pool::run_scoped;

/// How tile jobs reach their worker threads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Dispatch {
    /// The persistent process-wide worker pool (amortized spawn cost;
    /// the serving default).
    #[default]
    Pool,
    /// `std::thread::scope` spawn-per-call — the PR 1 engine, kept as
    /// the benchmark baseline for the pool.
    Spawn,
}

/// How many worker threads the kernels may use.
///
/// `Parallelism` is a *cap*, resolved lazily against the host: the
/// actual worker count for one kernel invocation also scales with the
/// amount of work (see [`Parallelism::workers_for`]) so tiny matmuls
/// never pay dispatch overhead.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    /// Maximum worker threads; `0` = resolve from the host
    /// (`BEANNA_WORKERS` env var, else `available_parallelism`).
    max_workers: usize,
    /// Worker dispatch strategy (pool by default).
    dispatch: Dispatch,
}

impl Parallelism {
    /// Single-threaded execution (the scalar reference behaviour).
    pub fn serial() -> Self {
        Self {
            max_workers: 1,
            dispatch: Dispatch::Pool,
        }
    }

    /// Exactly `n` workers at most (`n` is clamped to ≥ 1, so
    /// `fixed(0)` is a synonym for [`Parallelism::serial`]).
    pub fn fixed(n: usize) -> Self {
        Self {
            max_workers: n.max(1),
            dispatch: Dispatch::Pool,
        }
    }

    /// Resolve from the host at call time: the `BEANNA_WORKERS` env var
    /// if set, else `std::thread::available_parallelism`.
    pub fn auto() -> Self {
        Self {
            max_workers: 0,
            dispatch: Dispatch::Pool,
        }
    }

    /// Same budget, different dispatch strategy (benchmarking hook).
    pub fn with_dispatch(mut self, dispatch: Dispatch) -> Self {
        self.dispatch = dispatch;
        self
    }

    /// The dispatch strategy tile jobs will use.
    pub fn dispatch(&self) -> Dispatch {
        self.dispatch
    }

    /// The resolved worker cap for this configuration.
    pub fn max_workers(&self) -> usize {
        if self.max_workers > 0 {
            return self.max_workers;
        }
        let raw = std::env::var("BEANNA_WORKERS").ok();
        let parsed = parse_workers_env(raw.as_deref());
        match parsed {
            Some(Ok(n)) => return n,
            Some(Err(())) => {
                // Warn exactly once per process, then behave as auto.
                static WARN: std::sync::Once = std::sync::Once::new();
                WARN.call_once(|| {
                    eprintln!(
                        "warning: malformed BEANNA_WORKERS={:?} (want a positive integer); \
                         falling back to auto",
                        raw.unwrap_or_default()
                    );
                });
            }
            None => {}
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }

    /// Worker count for a kernel doing `ops` scalar inner-loop steps
    /// (MACs for the float kernels, packed-word XOR-popcounts for the
    /// binary kernel). Each worker must have at least
    /// [`MIN_OPS_PER_WORKER`] steps, so small problems stay serial.
    pub fn workers_for(&self, ops: usize) -> usize {
        (ops / MIN_OPS_PER_WORKER).clamp(1, self.max_workers())
    }

    /// Eagerly construct (and size to this budget) the process-wide
    /// worker pool this budget will dispatch to, so the first request
    /// of a serving session pays neither thread creation nor pool
    /// growth. No-op for serial budgets and for [`Dispatch::Spawn`].
    pub fn warm_pool(&self) {
        let _ = crate::util::pool::clamp_to_pool(self.dispatch, self.max_workers());
    }
}

impl Default for Parallelism {
    fn default() -> Self {
        Self::auto()
    }
}

/// Interpret a raw `BEANNA_WORKERS` value: `None` = unset,
/// `Some(Ok(n))` = a usable positive count, `Some(Err(()))` = malformed
/// (non-numeric, or zero) — callers fall back to auto with a warning.
pub fn parse_workers_env(raw: Option<&str>) -> Option<Result<usize, ()>> {
    let s = raw?;
    Some(match s.trim().parse::<usize>() {
        Ok(n) if n > 0 => Ok(n),
        _ => Err(()),
    })
}

/// Minimum inner-loop steps per worker before fanning out pays off
/// (~tens of microseconds of work against the dispatch overhead).
pub const MIN_OPS_PER_WORKER: usize = 32 * 1024;

/// [`par_tiles_with`] on the default pool dispatch.
pub fn par_tiles<K>(workers: usize, rows: usize, cols: usize, out: &mut [f32], kernel: K)
where
    K: Fn(Range<usize>, Range<usize>, &mut [f32]) + Sync,
{
    par_tiles_with(Dispatch::Pool, workers, rows, cols, out, kernel)
}

/// Run `kernel` over the `rows × cols` row-major output `out`, split
/// across up to `workers` tile jobs on the chosen [`Dispatch`].
///
/// `kernel(row_range, col_range, tile)` must fill `tile` — a row-major
/// `row_range.len() × col_range.len()` buffer (pre-zeroed) — with the
/// output sub-matrix for those ranges, computing each element exactly as
/// it would for the full `0..rows, 0..cols` call.
///
/// With `workers <= 1` (or an output too small to split) the kernel is
/// invoked once on the calling thread with the full range — this is the
/// serial path and the behavioural reference; it never touches the pool.
pub fn par_tiles_with<K>(
    dispatch: Dispatch,
    workers: usize,
    rows: usize,
    cols: usize,
    out: &mut [f32],
    kernel: K,
) where
    K: Fn(Range<usize>, Range<usize>, &mut [f32]) + Sync,
{
    par_tiles_aligned(dispatch, workers, rows, cols, 1, out, kernel)
}

/// [`par_tiles_with`] with column bands rounded up to a multiple of
/// `col_align` — the dispatched kernel's panel width — so a band
/// boundary never cuts a SIMD lane group in half (edge columns would
/// silently take the scalar path on *both* sides of the cut).
/// Alignment never changes results, only which columns land in which
/// band; `col_align = 1` is exactly [`par_tiles_with`].
pub fn par_tiles_aligned<K>(
    dispatch: Dispatch,
    workers: usize,
    rows: usize,
    cols: usize,
    col_align: usize,
    out: &mut [f32],
    kernel: K,
) where
    K: Fn(Range<usize>, Range<usize>, &mut [f32]) + Sync,
{
    assert_eq!(out.len(), rows * cols, "output buffer size mismatch");
    let col_align = col_align.max(1);
    let workers = workers.max(1).min(rows.max(1) * cols.max(1));
    if workers == 1 || rows == 0 || cols == 0 {
        kernel(0..rows, 0..cols, out);
        return;
    }
    // Grow the pool to an explicitly larger budget and never split
    // finer than the dispatch can actually run concurrently.
    let workers = super::pool::clamp_to_pool(dispatch, workers);
    if rows >= workers {
        // Row bands, written in place.
        let kernel = &kernel;
        super::pool::par_row_chunks_mut(dispatch, workers, cols, out, |r0, band| {
            kernel(r0..r0 + band.len() / cols, 0..cols, band)
        });
    } else if cols >= workers {
        // Column bands through private scratch tiles, band width
        // rounded up to the kernel's panel alignment.
        let band_cols = cols.div_ceil(workers).div_ceil(col_align) * col_align;
        let mut bands: Vec<(Range<usize>, Vec<f32>)> = (0..cols.div_ceil(band_cols))
            .map(|i| {
                let c0 = i * band_cols;
                let c1 = (c0 + band_cols).min(cols);
                (c0..c1, vec![0.0f32; rows * (c1 - c0)])
            })
            .collect();
        {
            let kernel = &kernel;
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = bands
                .iter_mut()
                .map(|(range, tile)| {
                    let range = range.clone();
                    let tile = tile.as_mut_slice();
                    Box::new(move || kernel(0..rows, range, tile))
                        as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            run_scoped(dispatch, jobs);
        }
        for (range, tile) in &bands {
            let w = range.len();
            for r in 0..rows {
                out[r * cols + range.start..r * cols + range.end]
                    .copy_from_slice(&tile[r * w..(r + 1) * w]);
            }
        }
    } else {
        // Output too small to split usefully.
        kernel(0..rows, 0..cols, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A deterministic per-element function so any partition must
    /// reproduce the serial result exactly.
    fn fill(rows: Range<usize>, cols: Range<usize>, tile: &mut [f32]) {
        let w = cols.len();
        for (ti, r) in rows.clone().enumerate() {
            for (tj, c) in cols.clone().enumerate() {
                tile[ti * w + tj] = (r * 1000 + c) as f32;
            }
        }
    }

    fn reference(rows: usize, cols: usize) -> Vec<f32> {
        let mut out = vec![0.0; rows * cols];
        fill(0..rows, 0..cols, &mut out);
        out
    }

    #[test]
    fn serial_path_covers_everything() {
        let (rows, cols) = (7, 5);
        let mut out = vec![0.0; rows * cols];
        par_tiles(1, rows, cols, &mut out, fill);
        assert_eq!(out, reference(rows, cols));
    }

    #[test]
    fn row_split_matches_serial_on_both_dispatches() {
        for dispatch in [Dispatch::Pool, Dispatch::Spawn] {
            for rows in [4usize, 7, 8, 9, 32] {
                let cols = 5;
                let mut out = vec![0.0; rows * cols];
                par_tiles_with(dispatch, 4, rows, cols, &mut out, fill);
                assert_eq!(out, reference(rows, cols), "rows={rows} {dispatch:?}");
            }
        }
    }

    #[test]
    fn col_split_matches_serial_on_both_dispatches() {
        // rows < workers forces the column-band path.
        for dispatch in [Dispatch::Pool, Dispatch::Spawn] {
            for cols in [8usize, 9, 17, 64] {
                let rows = 2;
                let mut out = vec![0.0; rows * cols];
                par_tiles_with(dispatch, 8, rows, cols, &mut out, fill);
                assert_eq!(out, reference(rows, cols), "cols={cols} {dispatch:?}");
            }
        }
    }

    #[test]
    fn aligned_col_split_matches_serial_for_any_alignment() {
        for align in [1usize, 4, 8, 16] {
            for cols in [8usize, 9, 17, 64] {
                let rows = 2;
                let mut out = vec![0.0; rows * cols];
                par_tiles_aligned(Dispatch::Pool, 8, rows, cols, align, &mut out, fill);
                assert_eq!(out, reference(rows, cols), "cols={cols} align={align}");
            }
        }
        // Alignment wider than the whole output collapses to one band.
        let mut out = vec![0.0; 2 * 6];
        par_tiles_aligned(Dispatch::Spawn, 4, 2, 6, 64, &mut out, fill);
        assert_eq!(out, reference(2, 6));
    }

    #[test]
    fn column_bands_start_on_alignment_boundaries() {
        use std::sync::Mutex;
        let starts = Mutex::new(Vec::new());
        let (rows, cols, align) = (2usize, 61usize, 8usize);
        let mut out = vec![0.0; rows * cols];
        par_tiles_aligned(Dispatch::Pool, 6, rows, cols, align, &mut out, |rr, cc, tile| {
            starts.lock().unwrap().push(cc.start);
            fill(rr, cc, tile);
        });
        let starts = starts.into_inner().unwrap();
        assert!(starts.len() > 1, "expected a column split, got {starts:?}");
        for s in starts {
            assert_eq!(s % align, 0, "band start {s} not {align}-aligned");
        }
        assert_eq!(out, reference(rows, cols));
    }

    #[test]
    fn tiny_outputs_fall_back_to_serial() {
        let mut out = vec![0.0; 4];
        par_tiles(16, 2, 2, &mut out, fill);
        assert_eq!(out, reference(2, 2));
        let mut empty: Vec<f32> = vec![];
        par_tiles(4, 0, 3, &mut empty, fill);
    }

    #[test]
    fn parallelism_heuristics() {
        assert_eq!(Parallelism::serial().max_workers(), 1);
        assert_eq!(Parallelism::fixed(3).max_workers(), 3);
        // fixed(0) clamps to 1 — the serial budget, never a panic.
        assert_eq!(Parallelism::fixed(0).max_workers(), 1);
        assert_eq!(Parallelism::fixed(0), Parallelism::serial());
        assert!(Parallelism::auto().max_workers() >= 1);
        // Small work stays serial; big work scales to the cap.
        let p = Parallelism::fixed(8);
        assert_eq!(p.workers_for(100), 1);
        assert_eq!(p.workers_for(MIN_OPS_PER_WORKER * 3), 3);
        assert_eq!(p.workers_for(usize::MAX / 2), 8);
        // Dispatch is carried by the budget and defaults to the pool.
        assert_eq!(p.dispatch(), Dispatch::Pool);
        assert_eq!(p.with_dispatch(Dispatch::Spawn).dispatch(), Dispatch::Spawn);
    }

    #[test]
    fn workers_env_parsing() {
        // Unset: defer to available_parallelism.
        assert_eq!(parse_workers_env(None), None);
        // Well-formed values (whitespace tolerated).
        assert_eq!(parse_workers_env(Some("4")), Some(Ok(4)));
        assert_eq!(parse_workers_env(Some(" 16 ")), Some(Ok(16)));
        // Malformed values fall back to auto (with a warning) rather
        // than being silently ignored or panicking.
        assert_eq!(parse_workers_env(Some("0")), Some(Err(())));
        assert_eq!(parse_workers_env(Some("-3")), Some(Err(())));
        assert_eq!(parse_workers_env(Some("lots")), Some(Err(())));
        assert_eq!(parse_workers_env(Some("")), Some(Err(())));
    }
}
