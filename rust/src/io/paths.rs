//! Artifact path resolution.
//!
//! All build-time outputs live under `artifacts/` (produced by
//! `make artifacts`): HLO text modules per model variant and batch size,
//! trained weights, and the Fig. 2 training curves.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Result};

/// Resolved artifact directory with typed accessors for each artifact.
#[derive(Debug, Clone)]
pub struct ArtifactPaths {
    /// Root artifacts directory.
    pub root: PathBuf,
}

impl ArtifactPaths {
    /// Use an explicit root.
    pub fn new(root: impl Into<PathBuf>) -> Self {
        Self { root: root.into() }
    }

    /// Locate `artifacts/` relative to the current directory or the
    /// `BEANNA_ARTIFACTS` environment variable.
    pub fn discover() -> Self {
        if let Ok(p) = std::env::var("BEANNA_ARTIFACTS") {
            return Self::new(p);
        }
        // Walk up from the CWD so examples/tests work from any subdir.
        let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            let cand = dir.join("artifacts");
            if cand.is_dir() {
                return Self::new(cand);
            }
            if !dir.pop() {
                return Self::new("artifacts");
            }
        }
    }

    /// HLO text module for a model variant (`"hybrid"` / `"fp"`) at a
    /// given batch size.
    pub fn hlo(&self, variant: &str, batch: usize) -> PathBuf {
        self.root.join(format!("model_{variant}_b{batch}.hlo.txt"))
    }

    /// Trained weights for a variant.
    pub fn weights(&self, variant: &str) -> PathBuf {
        self.root.join(format!("weights_{variant}.bwt"))
    }

    /// Synthetic-MNIST evaluation set (shared by both variants).
    pub fn dataset(&self) -> PathBuf {
        self.root.join("synth_mnist_test.bwt")
    }

    /// Fig. 2 training-curve CSV for a variant.
    pub fn fig2_csv(&self, variant: &str) -> PathBuf {
        self.root.join(format!("fig2_{variant}.csv"))
    }

    /// Check a path exists, with a helpful make hint.
    pub fn require(path: &Path) -> Result<&Path> {
        ensure!(
            path.exists(),
            "artifact {} missing — run `make artifacts` first",
            path.display()
        );
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_shapes() {
        let p = ArtifactPaths::new("/tmp/a");
        assert_eq!(
            p.hlo("hybrid", 256),
            PathBuf::from("/tmp/a/model_hybrid_b256.hlo.txt")
        );
        assert_eq!(p.weights("fp"), PathBuf::from("/tmp/a/weights_fp.bwt"));
        assert_eq!(
            p.fig2_csv("hybrid"),
            PathBuf::from("/tmp/a/fig2_hybrid.csv")
        );
    }

    #[test]
    fn require_reports_missing() {
        let err = ArtifactPaths::require(Path::new("/definitely/not/here.bwt"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("make artifacts"));
    }

    #[test]
    fn env_override() {
        std::env::set_var("BEANNA_ARTIFACTS", "/tmp/custom_artifacts");
        let p = ArtifactPaths::discover();
        assert_eq!(p.root, PathBuf::from("/tmp/custom_artifacts"));
        std::env::remove_var("BEANNA_ARTIFACTS");
    }
}
