//! `.bwt` named-tensor container (format documented in [`crate::io`]).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::bf16::Matrix;
use crate::binary::{BitMatrix, BitVector};

/// Element type of a stored tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit IEEE float.
    F32 = 0,
    /// Raw bfloat16 bit patterns (u16).
    BF16 = 1,
    /// Packed sign bits, 1 bit per element, row-padded to bytes.
    Bits = 2,
    /// 32-bit signed integer.
    I32 = 3,
    /// Unsigned byte.
    U8 = 4,
}

impl DType {
    fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            0 => DType::F32,
            1 => DType::BF16,
            2 => DType::Bits,
            3 => DType::I32,
            4 => DType::U8,
            _ => bail!("unknown dtype tag {v}"),
        })
    }
}

/// One stored tensor: dtype, shape, raw little-endian bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Element type.
    pub dtype: DType,
    /// Shape (row-major).
    pub shape: Vec<usize>,
    /// Raw data bytes, little-endian.
    pub data: Vec<u8>,
}

impl Tensor {
    /// Element count implied by the shape.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    /// Build an f32 tensor from values.
    pub fn from_f32(shape: &[usize], values: &[f32]) -> Result<Self> {
        ensure!(
            shape.iter().product::<usize>() == values.len(),
            "shape/value mismatch"
        );
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Ok(Self {
            dtype: DType::F32,
            shape: shape.to_vec(),
            data,
        })
    }

    /// Decode as a flat f32 vector (F32 and BF16 widen; I32/U8 convert).
    pub fn to_f32_vec(&self) -> Result<Vec<f32>> {
        let n = self.elements();
        match self.dtype {
            DType::F32 => {
                ensure!(self.data.len() == n * 4, "f32 payload size");
                Ok(self
                    .data
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect())
            }
            DType::BF16 => {
                ensure!(self.data.len() == n * 2, "bf16 payload size");
                Ok(self
                    .data
                    .chunks_exact(2)
                    .map(|b| {
                        crate::bf16::BF16::from_bits(u16::from_le_bytes([b[0], b[1]])).to_f32()
                    })
                    .collect())
            }
            DType::I32 => {
                ensure!(self.data.len() == n * 4, "i32 payload size");
                Ok(self
                    .data
                    .chunks_exact(4)
                    .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]) as f32)
                    .collect())
            }
            DType::U8 => {
                ensure!(self.data.len() == n, "u8 payload size");
                Ok(self.data.iter().map(|&b| b as f32).collect())
            }
            DType::Bits => {
                let m = self.to_bit_matrix()?;
                Ok(m.to_matrix().data)
            }
        }
    }

    /// Decode as a 2-D [`Matrix`]. 1-D tensors become a single row.
    pub fn to_matrix(&self) -> Result<Matrix> {
        let (rows, cols) = match self.shape.len() {
            1 => (1, self.shape[0]),
            2 => (self.shape[0], self.shape[1]),
            d => bail!("to_matrix needs 1-D/2-D, got {d}-D"),
        };
        Matrix::from_vec(rows, cols, self.to_f32_vec()?)
    }

    /// Decode a packed-bits tensor as a [`BitMatrix`].
    pub fn to_bit_matrix(&self) -> Result<BitMatrix> {
        ensure!(self.dtype == DType::Bits, "tensor is not packed bits");
        let (rows, cols) = match self.shape.len() {
            1 => (1, self.shape[0]),
            2 => (self.shape[0], self.shape[1]),
            d => bail!("to_bit_matrix needs 1-D/2-D, got {d}-D"),
        };
        let row_bytes = cols.div_ceil(8);
        ensure!(
            self.data.len() == rows * row_bytes,
            "bits payload: expected {} bytes, got {}",
            rows * row_bytes,
            self.data.len()
        );
        let mut row_bits = Vec::with_capacity(rows);
        for r in 0..rows {
            let bytes = &self.data[r * row_bytes..(r + 1) * row_bytes];
            let mut v = BitVector::ones(cols);
            for c in 0..cols {
                if (bytes[c / 8] >> (c % 8)) & 1 == 1 {
                    v.set(c, true);
                }
            }
            row_bits.push(v);
        }
        Ok(BitMatrix {
            rows,
            cols,
            row_bits,
        })
    }

    /// Encode a [`BitMatrix`] as a packed-bits tensor.
    pub fn from_bit_matrix(m: &BitMatrix) -> Self {
        let row_bytes = m.cols.div_ceil(8);
        let mut data = vec![0u8; m.rows * row_bytes];
        for (r, bits) in m.row_bits.iter().enumerate() {
            for c in 0..m.cols {
                if bits.get(c) {
                    data[r * row_bytes + c / 8] |= 1 << (c % 8);
                }
            }
        }
        Self {
            dtype: DType::Bits,
            shape: vec![m.rows, m.cols],
            data,
        }
    }
}

/// An ordered collection of named tensors — the on-disk `.bwt` unit.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TensorFile {
    /// Name → tensor, sorted for deterministic output.
    pub tensors: BTreeMap<String, Tensor>,
}

impl TensorFile {
    /// Empty container.
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert (replacing any same-named tensor).
    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }

    /// Fetch by name.
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("tensor '{name}' not in file"))
    }

    /// Serialize to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"BWT1");
        out.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, t) in &self.tensors {
            out.extend_from_slice(&(name.len() as u16).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.push(t.dtype as u8);
            out.push(t.shape.len() as u8);
            for &d in &t.shape {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            out.extend_from_slice(&(t.data.len() as u64).to_le_bytes());
            out.extend_from_slice(&t.data);
        }
        out
    }

    /// Parse from bytes.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut r = Cursor { buf: bytes, pos: 0 };
        let magic = r.take(4)?;
        ensure!(magic == b"BWT1", "bad magic {:?}", &magic);
        let count = r.u32()?;
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let name_len = r.u16()? as usize;
            let name = String::from_utf8(r.take(name_len)?.to_vec())
                .context("tensor name not utf-8")?;
            let dtype = DType::from_u8(r.u8()?)?;
            let ndim = r.u8()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(r.u32()? as usize);
            }
            let data_len = r.u64()? as usize;
            let data = r.take(data_len)?.to_vec();
            tensors.insert(name, Tensor { dtype, shape, data });
        }
        Ok(Self { tensors })
    }

    /// Write to a file.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    /// Read from a file.
    pub fn load(path: &Path) -> Result<Self> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?
            .read_to_end(&mut bytes)?;
        Self::from_bytes(&bytes).with_context(|| format!("parse {}", path.display()))
    }
}

/// Bounds-checked byte cursor for parsing.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.pos + n <= self.buf.len(),
            "truncated .bwt: need {} bytes at offset {}, have {}",
            n,
            self.pos,
            self.buf.len() - self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }
    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    #[test]
    fn roundtrip_f32() {
        let mut tf = TensorFile::new();
        tf.insert(
            "w0",
            Tensor::from_f32(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap(),
        );
        let back = TensorFile::from_bytes(&tf.to_bytes()).unwrap();
        assert_eq!(back, tf);
        let m = back.get("w0").unwrap().to_matrix().unwrap();
        assert_eq!(m.get(1, 2), 6.0);
    }

    #[test]
    fn roundtrip_bits() {
        let m = Matrix::from_vec(2, 10, crate::util::rng::Xoshiro256::seed_from_u64(3).normal_vec(20))
            .unwrap();
        let bm = BitMatrix::from_matrix(&m);
        let t = Tensor::from_bit_matrix(&bm);
        let mut tf = TensorFile::new();
        tf.insert("b", t);
        let back = TensorFile::from_bytes(&tf.to_bytes()).unwrap();
        assert_eq!(back.get("b").unwrap().to_bit_matrix().unwrap(), bm);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(TensorFile::from_bytes(b"NOPE\x00\x00\x00\x00").is_err());
        let tf = {
            let mut tf = TensorFile::new();
            tf.insert("x", Tensor::from_f32(&[4], &[1.0; 4]).unwrap());
            tf
        };
        let bytes = tf.to_bytes();
        for cut in [3, 8, 12, bytes.len() - 1] {
            assert!(
                TensorFile::from_bytes(&bytes[..cut]).is_err(),
                "cut at {cut} should fail"
            );
        }
    }

    #[test]
    fn missing_tensor_error() {
        let tf = TensorFile::new();
        assert!(tf.get("nope").is_err());
    }

    #[test]
    fn prop_roundtrip_arbitrary() {
        check(".bwt roundtrip", 60, |g: &mut Gen| {
            let mut tf = TensorFile::new();
            let n_tensors = g.usize_in(1..5);
            for i in 0..n_tensors {
                let (r, c) = g.dims(16);
                let vals: Vec<f32> = (0..r * c).map(|_| g.f32_in(-10.0, 10.0)).collect();
                tf.insert(
                    &format!("t{i}"),
                    Tensor::from_f32(&[r, c], &vals).unwrap(),
                );
            }
            let back = TensorFile::from_bytes(&tf.to_bytes())
                .map_err(|e| format!("parse failed: {e}"))?;
            if back == tf {
                Ok(())
            } else {
                Err("roundtrip mismatch".into())
            }
        });
    }

    #[test]
    fn file_save_load() {
        let dir = std::env::temp_dir().join("beanna_test_bwt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bwt");
        let mut tf = TensorFile::new();
        tf.insert("a", Tensor::from_f32(&[3], &[9.0, 8.0, 7.0]).unwrap());
        tf.save(&path).unwrap();
        let back = TensorFile::load(&path).unwrap();
        assert_eq!(back, tf);
        std::fs::remove_file(&path).ok();
    }
}
