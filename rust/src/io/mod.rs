//! Artifact I/O: the `.bwt` ("BEANNA weights/tensors") interchange format
//! and artifact path resolution.
//!
//! `.bwt` is a tiny named-tensor container written by `python/compile/`
//! (training, data generation) and read by the rust runtime — the crate
//! set has no serde/npy, so we define the format explicitly:
//!
//! ```text
//! magic   : 4 bytes  "BWT1"
//! count   : u32 LE   number of tensors
//! per tensor:
//!   name_len : u16 LE, name bytes (utf-8)
//!   dtype    : u8   (0 = f32, 1 = bf16 raw u16, 2 = packed bits u8,
//!                    3 = i32, 4 = u8)
//!   ndim     : u8, dims: ndim × u32 LE
//!   data_len : u64 LE, raw little-endian data bytes
//! ```
//!
//! All multi-byte values are little-endian. Packed-bit tensors (dtype 2)
//! store `ceil(last_dim/8)` bytes per leading-index row, LSB-first,
//! bit = 1 ⇔ −1 (matching [`crate::binary::BitVector`]).

pub mod bwt;
pub mod paths;

pub use bwt::{DType, Tensor, TensorFile};
pub use paths::ArtifactPaths;
