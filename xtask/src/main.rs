//! Repo-invariant linter: `cargo run -p xtask -- lint`.
//!
//! A line-wise static checker for the handful of repo-wide contracts
//! that rustc and clippy cannot see. It is deliberately *not* a Rust
//! parser — every rule is a textual invariant chosen so that a
//! line-oriented scan is sound for this codebase's style (rustfmt'd,
//! one statement per line). The rules:
//!
//! * **A — `unsafe` needs `// SAFETY:`.** Every line containing the
//!   `unsafe` keyword must be preceded (walking up through comments
//!   and attributes) by a `// SAFETY:` comment or a `/// # Safety`
//!   doc section.
//! * **B — no FMA in the numeric kernels.** `bf16`, `binary`, and
//!   `conv` code must never use fused multiply-add (`fmadd`/`vfma`
//!   intrinsics or `.mul_add(`): the repo's bit-exactness contract is
//!   defined by two-rounding mul+add chains.
//! * **C — no ad-hoc threads.** `std::thread::spawn` /
//!   `std::thread::Builder` appear only in `util/pool.rs`,
//!   `util/sync.rs`, `transport/`, and tests; everything else must go
//!   through the worker pool so loom models cover it.
//! * **D — no `.unwrap()` / `.expect(` on the serving path.**
//!   Non-test `coordinator/` and `transport/` code returns typed
//!   errors; panics there would take down the server.
//! * **E — bench keys exist in the baseline.** Every key a bench
//!   emits into a `BENCH_*.json` report must be present in
//!   `rust/BENCH_baseline.json`, so `perf_delta.py` can always
//!   compare it (`{hole}` placeholders match any `[a-z0-9_]+` run).
//!
//! Findings print as `file:line [rule] excerpt` and the process exits
//! non-zero, so the CI `lint-invariants` job gates on it.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => {}
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint");
            return ExitCode::from(2);
        }
    }
    // xtask/ sits directly under the repo root.
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask has a parent directory")
        .to_path_buf();
    match run_lint(&root) {
        Ok(findings) if findings.is_empty() => {
            println!("lint: ok");
            ExitCode::SUCCESS
        }
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            println!("lint: {} finding(s)", findings.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("lint: error: {e}");
            ExitCode::from(2)
        }
    }
}

/// One rule violation, displayed as `file:line [rule] excerpt`.
struct Finding {
    file: String,
    line: usize,
    rule: &'static str,
    excerpt: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{} [{}] {}", self.file, self.line, self.rule, self.excerpt)
    }
}

fn finding(file: &str, line_idx: usize, rule: &'static str, line: &str) -> Finding {
    Finding {
        file: file.to_string(),
        line: line_idx + 1,
        rule,
        excerpt: line.trim().chars().take(80).collect(),
    }
}

/// Run every rule over the repo rooted at `root`.
fn run_lint(root: &Path) -> Result<Vec<Finding>, String> {
    let mut findings = Vec::new();
    let src = root.join("rust").join("src");
    for path in rust_files(&src)? {
        let rel = rel_path(root, &path);
        let content =
            fs::read_to_string(&path).map_err(|e| format!("reading {}: {e}", path.display()))?;
        findings.extend(lint_unsafe(&rel, &content));
        findings.extend(lint_fma(&rel, &content));
        findings.extend(lint_spawn(&rel, &content));
        findings.extend(lint_unwrap(&rel, &content));
    }
    findings.extend(lint_bench_keys(root)?);
    Ok(findings)
}

/// All `.rs` files under `base`, depth-first, sorted within each dir.
fn rust_files(base: &Path) -> Result<Vec<PathBuf>, String> {
    let mut out = Vec::new();
    let mut stack = vec![base.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<PathBuf> = fs::read_dir(&dir)
            .map_err(|e| format!("reading {}: {e}", dir.display()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .collect();
        entries.sort();
        for p in entries {
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

fn rel_path(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .to_string_lossy()
        .replace(std::path::MAIN_SEPARATOR, "/")
}

/// Whether `line` is purely a comment (or blank) — such lines never
/// trigger a rule.
fn is_comment_line(line: &str) -> bool {
    let t = line.trim();
    t.is_empty() || t.starts_with("//")
}

/// Heuristic: is byte offset `pos` inside a string literal on this
/// line? Counts unescaped `"` before `pos` — good enough for
/// rustfmt'd single-line literals, which is all this repo has.
fn in_string(line: &str, pos: usize) -> bool {
    let b = line.as_bytes();
    let mut quotes = 0usize;
    let mut i = 0;
    while i < pos.min(b.len()) {
        if b[i] == b'"' {
            let mut backslashes = 0;
            let mut j = i;
            while j > 0 && b[j - 1] == b'\\' {
                backslashes += 1;
                j -= 1;
            }
            if backslashes % 2 == 0 {
                quotes += 1;
            }
        }
        i += 1;
    }
    quotes % 2 == 1
}

/// Find `pat` in `line` at a position outside any string literal.
fn find_code(line: &str, pat: &str) -> Option<usize> {
    let mut from = 0;
    while let Some(off) = line[from..].find(pat) {
        let pos = from + off;
        if !in_string(line, pos) {
            return Some(pos);
        }
        from = pos + pat.len();
    }
    None
}

/// Byte position of the word `unsafe` (with word boundaries, not in a
/// string, not preceded by `"`), if any.
fn find_unsafe_word(line: &str) -> Option<usize> {
    let b = line.as_bytes();
    let mut from = 0;
    while let Some(off) = line[from..].find("unsafe") {
        let pos = from + off;
        let before_ok = pos == 0 || {
            let c = b[pos - 1];
            !(c.is_ascii_alphanumeric() || c == b'_' || c == b'"')
        };
        let after = pos + "unsafe".len();
        let after_ok = after >= b.len() || {
            let c = b[after];
            !(c.is_ascii_alphanumeric() || c == b'_')
        };
        if before_ok && after_ok && !in_string(line, pos) {
            return Some(pos);
        }
        from = after;
    }
    None
}

/// Index of the first `#[cfg(test)]` / `#[cfg(all(test, …))]` line:
/// rules C and D only apply to lines before it. (This repo keeps all
/// test modules at the bottom of each file.)
fn test_cutoff(lines: &[&str]) -> usize {
    lines
        .iter()
        .position(|l| {
            let t = l.trim();
            t.starts_with("#[cfg(test)]") || t.starts_with("#[cfg(all(test")
        })
        .unwrap_or(lines.len())
}

/// Rule A: every `unsafe` is justified by a `// SAFETY:` comment (or a
/// `/// # Safety` doc section) directly above it, skipping attributes.
fn lint_unsafe(rel: &str, content: &str) -> Vec<Finding> {
    let lines: Vec<&str> = content.lines().collect();
    let mut out = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        if is_comment_line(line) || find_unsafe_word(line).is_none() {
            continue;
        }
        let mut justified = false;
        let mut j = i;
        while j > 0 {
            let above = lines[j - 1].trim();
            if above.starts_with("//") {
                if above.contains("SAFETY:") || above.contains("# Safety") {
                    justified = true;
                }
                j -= 1;
            } else if above.starts_with("#[") || above.starts_with("#![") {
                j -= 1;
            } else {
                break;
            }
        }
        if !justified {
            out.push(finding(rel, i, "A-unsafe-no-safety", line));
        }
    }
    out
}

/// Rule B: no fused multiply-add in the numeric kernels.
fn lint_fma(rel: &str, content: &str) -> Vec<Finding> {
    let numeric = rel.contains("/bf16/") || rel.contains("/binary/") || rel.contains("/conv/");
    if !numeric {
        return Vec::new();
    }
    const PATTERNS: [&str; 5] = ["fmadd", "fmsub", "vfma", "vfms", ".mul_add("];
    let mut out = Vec::new();
    for (i, line) in content.lines().enumerate() {
        if is_comment_line(line) {
            continue;
        }
        if PATTERNS.iter().any(|p| find_code(line, p).is_some()) {
            out.push(finding(rel, i, "B-fma", line));
        }
    }
    out
}

/// Rule C: thread spawns live only in the pool, the sync shim, and the
/// transport layer (plus tests).
fn lint_spawn(rel: &str, content: &str) -> Vec<Finding> {
    let allowed = rel.ends_with("util/pool.rs")
        || rel.ends_with("util/sync.rs")
        || rel.contains("/transport/");
    if allowed {
        return Vec::new();
    }
    let lines: Vec<&str> = content.lines().collect();
    let cutoff = test_cutoff(&lines);
    let mut out = Vec::new();
    for (i, line) in lines[..cutoff].iter().enumerate() {
        if is_comment_line(line) {
            continue;
        }
        if find_code(line, "std::thread::spawn").is_some()
            || find_code(line, "std::thread::Builder").is_some()
        {
            out.push(finding(rel, i, "C-spawn", line));
        }
    }
    out
}

/// Rule D: no `.unwrap()` / `.expect(` in non-test serving code.
fn lint_unwrap(rel: &str, content: &str) -> Vec<Finding> {
    if !(rel.contains("/coordinator/") || rel.contains("/transport/")) {
        return Vec::new();
    }
    let lines: Vec<&str> = content.lines().collect();
    let cutoff = test_cutoff(&lines);
    let mut out = Vec::new();
    for (i, line) in lines[..cutoff].iter().enumerate() {
        if is_comment_line(line) {
            continue;
        }
        if find_code(line, ".unwrap()").is_some() || find_code(line, ".expect(").is_some() {
            out.push(finding(rel, i, "D-unwrap", line));
        }
    }
    out
}

// ---------------------------------------------------------------- rule E

/// Rule E: every key a bench emits (string literals near a `JsonValue`
/// construction) exists in `rust/BENCH_baseline.json`.
fn lint_bench_keys(root: &Path) -> Result<Vec<Finding>, String> {
    let baseline_path = root.join("rust").join("BENCH_baseline.json");
    let baseline = fs::read_to_string(&baseline_path)
        .map_err(|e| format!("reading {}: {e}", baseline_path.display()))?;
    let keys = flat_json_keys(&baseline);
    let mut out = Vec::new();
    for dir in [root.join("rust").join("benches"), root.join("examples")] {
        for path in rust_files(&dir)? {
            let content = fs::read_to_string(&path)
                .map_err(|e| format!("reading {}: {e}", path.display()))?;
            if !content.contains("BENCH_") {
                continue;
            }
            out.extend(check_bench_file(&rel_path(root, &path), &content, &keys));
        }
    }
    Ok(out)
}

fn check_bench_file(rel: &str, content: &str, baseline_keys: &[String]) -> Vec<Finding> {
    let lines: Vec<&str> = content.lines().collect();
    let mut out = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        if is_comment_line(line) {
            continue;
        }
        // Only lines in a 3-line window that mentions `JsonValue` are
        // report-key constructions; everything else (log text, ids) is
        // not a bench key.
        let window_hit = lines[i..lines.len().min(i + 3)]
            .iter()
            .any(|l| l.contains("JsonValue"));
        if !window_hit {
            continue;
        }
        for lit in string_literals(line) {
            if !looks_like_bench_key(&lit) {
                continue;
            }
            let known = if lit.contains('{') {
                baseline_keys.iter().any(|k| matches_with_holes(&lit, k))
            } else {
                baseline_keys.iter().any(|k| k == &lit)
            };
            if !known {
                out.push(Finding {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: "E-benchkey",
                    excerpt: lit,
                });
            }
        }
    }
    out
}

/// The string literals on one line (contents only, escapes untouched).
fn string_literals(line: &str) -> Vec<String> {
    let b = line.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < b.len() {
        if b[i] == b'"' {
            let start = i + 1;
            let mut j = start;
            while j < b.len() && b[j] != b'"' {
                if b[j] == b'\\' {
                    j += 1;
                }
                j += 1;
            }
            if j <= b.len() {
                out.push(line[start..j.min(b.len())].to_string());
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    out
}

/// Snake-case bench-key shape: starts `[a-z]`, all chars in
/// `[a-z0-9_{}]`, and has an interior `_` — so `"bf16_scalar_gops"`
/// and `"qos_{label}_p50_ms"` qualify but `"avx2"` or log text don't.
fn looks_like_bench_key(s: &str) -> bool {
    fn key_char(c: u8) -> bool {
        c.is_ascii_lowercase() || c.is_ascii_digit() || c == b'_' || c == b'{' || c == b'}'
    }
    let b = s.as_bytes();
    if b.is_empty() || !b[0].is_ascii_lowercase() || !b.iter().all(|&c| key_char(c)) {
        return false;
    }
    s.find('_').is_some_and(|p| p + 1 < s.len())
}

/// Match a key template with `{hole}` placeholders against a concrete
/// baseline key; each hole stands for one-or-more `[a-z0-9_]` chars.
fn matches_with_holes(template: &str, key: &str) -> bool {
    enum Seg {
        Lit(String),
        Hole,
    }
    let mut segs = Vec::new();
    let mut rest = template;
    while let Some(open) = rest.find('{') {
        if open > 0 {
            segs.push(Seg::Lit(rest[..open].to_string()));
        }
        match rest[open..].find('}') {
            Some(close) => {
                segs.push(Seg::Hole);
                rest = &rest[open + close + 1..];
            }
            None => return false, // unbalanced template: never matches
        }
    }
    if !rest.is_empty() {
        segs.push(Seg::Lit(rest.to_string()));
    }
    fn go(segs: &[Seg], k: &str) -> bool {
        match segs.split_first() {
            None => k.is_empty(),
            Some((Seg::Lit(l), rest)) => match k.strip_prefix(l.as_str()) {
                Some(r) => go(rest, r),
                None => false,
            },
            Some((Seg::Hole, rest)) => {
                let run = k
                    .bytes()
                    .take_while(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || *c == b'_')
                    .count();
                (1..=run).any(|take| go(rest, &k[take..]))
            }
        }
    }
    go(&segs, key)
}

/// Top-level keys of a flat JSON object — a hand-rolled scan (no JSON
/// dependency): a string at nesting depth 1 followed by `:` is a key.
fn flat_json_keys(json: &str) -> Vec<String> {
    let b = json.as_bytes();
    let mut keys = Vec::new();
    let mut depth = 0i32;
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'{' | b'[' => {
                depth += 1;
                i += 1;
            }
            b'}' | b']' => {
                depth -= 1;
                i += 1;
            }
            b'"' => {
                let start = i + 1;
                let mut j = start;
                while j < b.len() && b[j] != b'"' {
                    if b[j] == b'\\' {
                        j += 1;
                    }
                    j += 1;
                }
                let lit = &json[start..j.min(b.len())];
                let mut k = j + 1;
                while k < b.len() && b[k].is_ascii_whitespace() {
                    k += 1;
                }
                if depth == 1 && k < b.len() && b[k] == b':' {
                    keys.push(lit.to_string());
                }
                i = j + 1;
            }
            _ => i += 1,
        }
    }
    keys
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unsafe_without_safety_is_flagged() {
        let bad = "fn f() {\n    unsafe { g() };\n}\n";
        let hits = lint_unsafe("rust/src/x.rs", bad);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].line, 2);
        assert_eq!(hits[0].rule, "A-unsafe-no-safety");
        let shown = "rust/src/x.rs:2 [A-unsafe-no-safety] unsafe { g() };";
        assert_eq!(hits[0].to_string(), shown);
    }

    #[test]
    fn unsafe_with_safety_comment_passes() {
        let good = concat!(
            "fn f() {\n",
            "    // SAFETY: g has no preconditions here.\n",
            "    unsafe { g() };\n",
            "}\n"
        );
        assert!(lint_unsafe("rust/src/x.rs", good).is_empty());
    }

    #[test]
    fn safety_comment_is_found_through_attributes() {
        let good = concat!(
            "/// Docs.\n///\n/// # Safety\n///\n/// Caller checks AVX2.\n",
            "#[target_feature(enable = \"avx2\")]\n",
            "unsafe fn f() {}\n"
        );
        assert!(lint_unsafe("rust/src/x.rs", good).is_empty());
    }

    #[test]
    fn unsafe_inside_a_string_or_word_is_ignored() {
        let fine = "let s = \"unsafe\";\nlet unsafety = 1;\n";
        assert!(lint_unsafe("rust/src/x.rs", fine).is_empty());
    }

    #[test]
    fn fma_in_kernels_is_flagged_and_elsewhere_ignored() {
        let line = "let y = x.mul_add(a, b);\n";
        assert_eq!(lint_fma("rust/src/bf16/kernels.rs", line).len(), 1);
        assert_eq!(lint_fma("rust/src/binary/kernels.rs", line).len(), 1);
        assert!(lint_fma("rust/src/model/power.rs", line).is_empty());
        // Mentioning FMA in a comment is fine.
        let comment = "// never vfmaq_f32: two-rounding contract\n";
        assert!(lint_fma("rust/src/bf16/kernels.rs", comment).is_empty());
    }

    #[test]
    fn spawn_outside_the_pool_is_flagged() {
        let bad = "fn f() {\n    std::thread::spawn(|| {});\n}\n";
        assert_eq!(lint_spawn("rust/src/coordinator/server.rs", bad).len(), 1);
        assert!(lint_spawn("rust/src/util/pool.rs", bad).is_empty());
        assert!(lint_spawn("rust/src/transport/worker.rs", bad).is_empty());
        // In tests it is fine anywhere.
        let test_only = concat!(
            "#[cfg(test)]\nmod tests {\n",
            "    fn f() { std::thread::spawn(|| {}); }\n}\n"
        );
        assert!(lint_spawn("rust/src/coordinator/server.rs", test_only).is_empty());
    }

    #[test]
    fn unwrap_on_the_serving_path_is_flagged() {
        let bad = "fn f() {\n    x.lock().unwrap();\n}\n";
        assert_eq!(lint_unwrap("rust/src/coordinator/metrics.rs", bad).len(), 1);
        assert_eq!(lint_unwrap("rust/src/transport/frame.rs", bad).len(), 1);
        assert!(lint_unwrap("rust/src/bf16/kernels.rs", bad).is_empty());
        // Below the test marker it is fine — loom cfg included.
        let loom = concat!(
            "#[cfg(all(test, beanna_loom))]\nmod loom_tests {\n",
            "    fn f() { x.join().expect(\"t\"); }\n}\n"
        );
        assert!(lint_unwrap("rust/src/coordinator/router.rs", loom).is_empty());
    }

    #[test]
    fn bench_keys_match_the_baseline() {
        let keys = vec!["bf16_scalar_gops".to_string(), "qos_1x_reject_rate".to_string()];
        let known = "report.push((\"bf16_scalar_gops\".into(), JsonValue::n(g)));\n";
        assert!(check_bench_file("rust/benches/b.rs", known, &keys).is_empty());
        let unknown = "report.push((\"bf16_turbo_gops\".into(), JsonValue::n(g)));\n";
        let hits = check_bench_file("rust/benches/b.rs", unknown, &keys);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].excerpt, "bf16_turbo_gops");
        // A key template with a hole matches any concrete instance.
        let hole = "report.push((format!(\"qos_{label}_reject_rate\"), JsonValue::n(r)));\n";
        assert!(check_bench_file("rust/benches/b.rs", hole, &keys).is_empty());
        // Literals far from any JsonValue construction are not keys.
        let log = "println!(\"bf16_turbo_gops\");\n";
        assert!(check_bench_file("rust/benches/b.rs", log, &keys).is_empty());
    }

    #[test]
    fn flat_json_keys_reads_top_level_only() {
        let json = "{\n  \"a_b\": 1.5,\n  \"c_d\": {\"nested_k\": 2},\n  \"e_f\": \"a: b\"\n}\n";
        assert_eq!(flat_json_keys(json), vec!["a_b", "c_d", "e_f"]);
    }

    #[test]
    fn key_shape_filter_rejects_prose() {
        assert!(looks_like_bench_key("bf16_scalar_gops"));
        assert!(looks_like_bench_key("qos_{label}_p50_ms"));
        assert!(!looks_like_bench_key("avx2"));
        assert!(!looks_like_bench_key("Tag_name"));
        assert!(!looks_like_bench_key("has spaces_here"));
        assert!(!looks_like_bench_key("trailing_"));
    }

    #[test]
    fn hole_matching_requires_full_anchored_match() {
        assert!(matches_with_holes("qos_{l}_p50_ms", "qos_1x_p50_ms"));
        assert!(matches_with_holes("chaos_{m}_fail_rate", "chaos_noretry_fail_rate"));
        assert!(!matches_with_holes("qos_{l}_p50_ms", "qos_1x_p99_ms"));
        assert!(!matches_with_holes("qos_{l}_p50_ms", "xqos_1x_p50_ms"));
        assert!(!matches_with_holes("a_{h}", "a_"));
    }
}
