#!/usr/bin/env python3
"""Regenerate the committed-baseline performance tables in
rust/README.md from rust/BENCH_baseline.json.

The README carries marked regions:

    <!-- bench-tables:begin NAME -->
    ...generated table...
    <!-- bench-tables:end NAME -->

This script rewrites each region from the baseline JSON so the prose
tables can never drift from the committed numbers. Keys missing from
the baseline are skipped (e.g. per-ISA keys a runner didn't produce),
so the script is safe against partial baselines.

Usage:
    python3 scripts/bench_tables.py            # rewrite in place
    python3 scripts/bench_tables.py --check    # exit 1 if out of date
                                               # (CI runs this)

Paths are resolved relative to this file, so it works from any CWD.
"""

import json
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
BASELINE = ROOT / "rust" / "BENCH_baseline.json"
README = ROOT / "rust" / "README.md"

MARKER = re.compile(
    r"(<!-- bench-tables:begin (?P<name>[\w-]+) -->\n)"
    r".*?"
    r"(<!-- bench-tables:end (?P=name) -->)",
    re.DOTALL,
)

# name -> (caption, header row, [(label, key, format)])
TABLES = {
    "hot-paths": (
        "Hot-path throughput on the paper layer "
        "(256×1024 · (1024×1024)ᵀ), scalar-pinned historical keys:",
        ("path", "GOps/s"),
        [
            ("bf16 scalar blocked-ᵀ", "bf16_scalar_gops", "{:.1f}"),
            ("bf16 parallel", "bf16_parallel_gops", "{:.1f}"),
            ("bf16 packed-parallel", "bf16_packed_gops", "{:.1f}"),
            ("binary naive dot", "binary_naive_gops", "{:.0f}"),
            ("binary tiled", "binary_tiled_gops", "{:.0f}"),
            ("binary parallel", "binary_parallel_gops", "{:.0f}"),
        ],
    ),
    "dispatch": (
        "Dispatched SIMD kernels (same shape; best kernel: "
        "`{kernel_best}`):",
        ("kernel", "GOps/s"),
        [
            ("bf16 avx2", "bf16_avx2_gops", "{:.1f}"),
            ("bf16 neon", "bf16_neon_gops", "{:.1f}"),
            ("bf16 best", "bf16_best_gops", "{:.1f}"),
            ("binary avx2", "binary_avx2_gops", "{:.0f}"),
            ("binary neon", "binary_neon_gops", "{:.0f}"),
            ("binary best", "binary_best_gops", "{:.0f}"),
        ],
    ),
}


def render(name, baseline):
    caption, header, rows = TABLES[name]
    caption = caption.format(kernel_best=baseline.get("kernel_best", "?"))
    lines = [caption, ""]
    lines.append("| " + " | ".join(header) + " |")
    lines.append("|" + "---|" * len(header))
    for label, key, fmt in rows:
        value = baseline.get(key)
        if not isinstance(value, (int, float)) or isinstance(value, bool):
            continue  # key absent from this baseline — skip the row
        lines.append(f"| {label} | {fmt.format(value)} |")
    return "\n".join(lines)


def main():
    check = "--check" in sys.argv[1:]
    baseline = json.loads(BASELINE.read_text())
    text = README.read_text()

    seen = set()

    def replace(m):
        name = m.group("name")
        seen.add(name)
        if name not in TABLES:
            print(f"bench-tables: no generator for region '{name}'")
            sys.exit(1)
        return m.group(1) + render(name, baseline) + "\n" + m.group(3)

    updated = MARKER.sub(replace, text)
    missing = set(TABLES) - seen
    if missing:
        print(f"bench-tables: README regions missing: {sorted(missing)}")
        sys.exit(1)

    if check:
        if updated != text:
            print(
                "bench-tables: rust/README.md tables are out of date with "
                "rust/BENCH_baseline.json — run scripts/bench_tables.py"
            )
            sys.exit(1)
        print("bench-tables: README tables in sync with the baseline")
    elif updated != text:
        README.write_text(updated)
        print("bench-tables: rewrote README tables from the baseline")
    else:
        print("bench-tables: README tables already in sync")


if __name__ == "__main__":
    main()
